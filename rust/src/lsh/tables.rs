//! The (K, L) hash-table structure of Appendix A.1 / Figure 7.
//!
//! `L` independent tables, each keyed by a K-bit meta-hash code, each bucket
//! holding the *ids* of the stored points (never the vectors themselves —
//! the paper stores pointers for memory efficiency; we store `u32` ids into
//! the caller's dataset).
//!
//! Building the tables is the one-time preprocessing cost of LGD; queries
//! and incremental inserts/removes are O(K·density·d) per table.

use std::collections::{BTreeMap, HashMap};

use crate::core::error::{Error, Result};
use crate::lsh::srp::SrpHasher;

/// Bucket storage for one table: direct-indexed array for small key spaces
/// (K ≤ 12 — the paper's K=5 gives 32 buckets), HashMap beyond. The dense
/// variant turns the per-probe bucket lookup into one array index — a
/// measurable win on the Algorithm-1 hot path (§Perf). The dense variant
/// additionally keeps an incremental occupancy index (`occupied`/`pos`) so
/// `non_empty()` is O(1) and bucket iteration — hence [`TableStats`] — is
/// O(non-empty) instead of O(2^K) per call, cheap enough to sample inside
/// the training loop.
#[derive(Clone)]
enum Buckets {
    Dense {
        slots: Vec<Vec<u32>>,
        /// Codes whose slot is non-empty (unordered; swap-removed).
        occupied: Vec<u32>,
        /// code → index in `occupied` (u32::MAX = empty slot).
        pos: Vec<u32>,
    },
    Map(HashMap<u32, Vec<u32>>),
}

impl Buckets {
    fn new(k: usize) -> Self {
        if k <= 12 {
            let n = 1usize << k;
            Buckets::Dense {
                slots: (0..n).map(|_| Vec::new()).collect(),
                occupied: Vec::new(),
                pos: vec![u32::MAX; n],
            }
        } else {
            Buckets::Map(HashMap::new())
        }
    }

    #[inline]
    fn get(&self, code: u32) -> &[u32] {
        match self {
            Buckets::Dense { slots, .. } => {
                slots.get(code as usize).map(|b| b.as_slice()).unwrap_or(&[])
            }
            Buckets::Map(m) => m.get(&code).map(|b| b.as_slice()).unwrap_or(&[]),
        }
    }

    #[inline]
    fn push(&mut self, code: u32, id: u32) {
        match self {
            Buckets::Dense { slots, occupied, pos } => {
                let slot = &mut slots[code as usize];
                if slot.is_empty() {
                    pos[code as usize] = occupied.len() as u32;
                    occupied.push(code);
                }
                slot.push(id);
            }
            Buckets::Map(m) => m.entry(code).or_default().push(id),
        }
    }

    fn remove_id(&mut self, code: u32, id: u32) -> bool {
        match self {
            Buckets::Dense { slots, occupied, pos } => {
                let slot = &mut slots[code as usize];
                if let Some(p) = slot.iter().position(|&v| v == id) {
                    slot.swap_remove(p);
                    if slot.is_empty() {
                        let at = pos[code as usize] as usize;
                        occupied.swap_remove(at);
                        if at < occupied.len() {
                            pos[occupied[at] as usize] = at as u32;
                        }
                        pos[code as usize] = u32::MAX;
                    }
                    true
                } else {
                    false
                }
            }
            Buckets::Map(m) => {
                let b = match m.get_mut(&code) {
                    Some(b) => b,
                    None => return false,
                };
                if let Some(p) = b.iter().position(|&v| v == id) {
                    b.swap_remove(p);
                    if b.is_empty() {
                        m.remove(&code);
                    }
                    true
                } else {
                    false
                }
            }
        }
    }

    fn clear(&mut self) {
        match self {
            Buckets::Dense { slots, occupied, pos } => {
                slots.iter_mut().for_each(|b| b.clear());
                occupied.clear();
                pos.iter_mut().for_each(|p| *p = u32::MAX);
            }
            Buckets::Map(m) => m.clear(),
        }
    }

    fn non_empty(&self) -> usize {
        match self {
            Buckets::Dense { occupied, .. } => occupied.len(),
            Buckets::Map(m) => m.len(),
        }
    }

    fn for_each_bucket(&self, mut f: impl FnMut(&[u32])) {
        match self {
            Buckets::Dense { slots, occupied, .. } => {
                occupied.iter().for_each(|&c| f(&slots[c as usize]))
            }
            Buckets::Map(m) => m.values().for_each(|b| f(b)),
        }
    }

    /// Non-empty (code, bucket) pairs in ascending code order — the
    /// deterministic layout `seal()` flattens.
    fn sorted_buckets(&self) -> Vec<(u32, &[u32])> {
        match self {
            Buckets::Dense { slots, occupied, .. } => {
                let mut codes: Vec<u32> = occupied.clone();
                codes.sort_unstable();
                codes.into_iter().map(|c| (c, slots[c as usize].as_slice())).collect()
            }
            Buckets::Map(m) => {
                let mut codes: Vec<u32> = m.keys().copied().collect();
                codes.sort_unstable();
                codes.into_iter().map(|c| (c, m[&c].as_slice())).collect()
            }
        }
    }
}

/// A borrowed view of one bucket. Sealed tables may split a live bucket
/// across the CSR arena segment (`head`) and the delta overlay (`tail`);
/// Vec-backed tables always have an empty tail. The effective bucket is the
/// concatenation, and its element *order* is part of the draw stream
/// (uniform in-bucket picks), so both backends maintain identical order.
#[derive(Debug, Clone, Copy, Default)]
pub struct BucketView<'a> {
    head: &'a [u32],
    tail: &'a [u32],
}

impl<'a> BucketView<'a> {
    /// View over an arena segment plus an overlay tail.
    #[inline]
    pub fn new(head: &'a [u32], tail: &'a [u32]) -> Self {
        BucketView { head, tail }
    }

    /// Number of ids in the bucket.
    #[inline]
    pub fn len(&self) -> usize {
        self.head.len() + self.tail.len()
    }

    /// True if the bucket holds no ids.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.head.is_empty() && self.tail.is_empty()
    }

    /// Id at position `i` of the effective bucket.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        if i < self.head.len() {
            self.head[i]
        } else {
            self.tail[i - self.head.len()]
        }
    }

    /// Ids in effective-bucket order.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.head.iter().chain(self.tail.iter()).copied()
    }

    /// Materialise the effective bucket (tests/diagnostics).
    pub fn to_vec(&self) -> Vec<u32> {
        self.iter().collect()
    }
}

/// Read-only bucket access — the surface [`crate::lsh::sampler::LshSampler`]
/// draws through, implemented by the Vec-backed [`LshTables`], the CSR
/// [`SealedTables`], and the [`TableStore`] dispatcher.
pub trait BucketRead: Send + Sync {
    /// The hash family keying the tables.
    type H: SrpHasher;

    /// The wrapped hasher.
    fn hasher(&self) -> &Self::H;

    /// The bucket of table `t` under an explicit (precomputed) code.
    fn view(&self, t: usize, code: u32) -> BucketView<'_>;

    /// Union of the query's buckets over all L tables, deduplicated in
    /// first-seen order — the *near-neighbor candidate set* of Appendix
    /// A.1, used by the §2.2.1 cost comparison (this is exactly the work
    /// LGD avoids). Defined once here so every layout shares the same
    /// candidate-set semantics.
    fn candidate_union(&self, query: &[f32]) -> Vec<u32> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for t in 0..self.hasher().l() {
            let code = self.hasher().code(t, query);
            for id in self.view(t, code).iter() {
                if seen.insert(id) {
                    out.push(id);
                }
            }
        }
        out
    }
}

/// L hash tables over point ids.
#[derive(Clone)]
pub struct LshTables<H: SrpHasher> {
    hasher: H,
    /// tables[t] : code -> point ids
    tables: Vec<Buckets>,
    /// number of points inserted
    len: usize,
}

/// Bucket-occupancy statistics (diagnostics + table-tuning experiments).
#[derive(Debug, Clone, PartialEq)]
pub struct TableStats {
    /// Total number of (non-empty) buckets across all tables.
    pub buckets: usize,
    /// Mean bucket size over non-empty buckets.
    pub mean_bucket: f64,
    /// Largest bucket size.
    pub max_bucket: usize,
    /// Fraction of the 2^K key space occupied, averaged over tables.
    pub occupancy: f64,
}

impl<H: SrpHasher> LshTables<H> {
    /// Empty tables wrapping `hasher`.
    pub fn new(hasher: H) -> Self {
        let l = hasher.l();
        let k = hasher.k();
        LshTables { hasher, tables: (0..l).map(|_| Buckets::new(k)).collect(), len: 0 }
    }

    /// Build from a set of row vectors (`rows[i]` inserted with id `i`).
    pub fn build<'a, I>(hasher: H, rows: I) -> Result<Self>
    where
        I: IntoIterator<Item = &'a [f32]>,
    {
        let mut t = Self::new(hasher);
        for (i, r) in rows.into_iter().enumerate() {
            t.insert(i as u32, r)?;
        }
        Ok(t)
    }

    /// Insert a point id with its vector into every table.
    pub fn insert(&mut self, id: u32, x: &[f32]) -> Result<()> {
        if x.len() != self.hasher.dim() {
            return Err(Error::Lsh(format!(
                "insert dim {} into hasher dim {}",
                x.len(),
                self.hasher.dim()
            )));
        }
        for t in 0..self.tables.len() {
            let code = self.hasher.code(t, x);
            self.tables[t].push(code, id);
        }
        self.len += 1;
        Ok(())
    }

    /// Insert a pre-computed (table, code) pair for `id`. Pipeline building
    /// block: hash workers compute codes in parallel and a single owner
    /// thread applies them. The caller is responsible for covering every
    /// table exactly once per id; `finish_coded_inserts` sets the length.
    #[inline]
    pub fn insert_coded(&mut self, table: usize, code: u32, id: u32) {
        self.tables[table].push(code, id);
    }

    /// Declare how many distinct ids were inserted via `insert_coded`.
    pub fn finish_coded_inserts(&mut self, n: usize) {
        self.len = n;
    }

    /// Remove a point id (requires the same vector it was inserted with).
    /// Returns true if found in all tables.
    pub fn remove(&mut self, id: u32, x: &[f32]) -> bool {
        let mut found_everywhere = true;
        for t in 0..self.tables.len() {
            let code = self.hasher.code(t, x);
            if !self.tables[t].remove_id(code, id) {
                found_everywhere = false;
            }
        }
        if found_everywhere && self.len > 0 {
            self.len -= 1;
        }
        found_everywhere
    }

    /// Number of inserted points.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no points inserted.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The wrapped hasher.
    pub fn hasher(&self) -> &H {
        &self.hasher
    }

    /// The bucket in table `t` matching the query (computes the query's
    /// meta-hash for that table only — the Algorithm 1 cost model).
    #[inline]
    pub fn query_bucket(&self, t: usize, query: &[f32]) -> &[u32] {
        let code = self.hasher.code(t, query);
        self.bucket(t, code)
    }

    /// The bucket in table `t` under an explicit code.
    #[inline]
    pub fn bucket(&self, t: usize, code: u32) -> &[u32] {
        self.tables[t].get(code)
    }

    /// Occupancy statistics.
    pub fn stats(&self) -> TableStats {
        let mut buckets = 0usize;
        let mut total = 0usize;
        let mut max_bucket = 0usize;
        for t in &self.tables {
            buckets += t.non_empty();
            t.for_each_bucket(|b| {
                total += b.len();
                max_bucket = max_bucket.max(b.len());
            });
        }
        let key_space = (1u64 << self.hasher.k()) as f64;
        let occupancy = if self.tables.is_empty() {
            0.0
        } else {
            self.tables.iter().map(|t| t.non_empty() as f64 / key_space).sum::<f64>()
                / self.tables.len() as f64
        };
        TableStats {
            buckets,
            mean_bucket: if buckets == 0 { 0.0 } else { total as f64 / buckets as f64 },
            max_bucket,
            occupancy,
        }
    }

    /// Rebuild all tables from scratch with new vectors (Appendix E: BERT
    /// pooled representations drift during fine-tuning and are re-hashed
    /// periodically). Ids are assigned 0..rows.len().
    pub fn rebuild<'a, I>(&mut self, rows: I) -> Result<()>
    where
        I: IntoIterator<Item = &'a [f32]>,
    {
        for t in self.tables.iter_mut() {
            t.clear();
        }
        self.len = 0;
        for (i, r) in rows.into_iter().enumerate() {
            self.insert(i as u32, r)?;
        }
        Ok(())
    }

    /// Flatten into the CSR bucket arena (see [`SealedTables`]). Bucket
    /// contents keep their exact order, so a sampler draws the identical
    /// sequence over the sealed layout under the same seed.
    pub fn seal(self) -> SealedTables<H> {
        let k = self.hasher.k();
        let sealed = self.tables.iter().map(|b| SealedTable::seal(k, b)).collect();
        SealedTables { hasher: self.hasher, tables: sealed, len: self.len }
    }
}

impl<H: SrpHasher> BucketRead for LshTables<H> {
    type H = H;

    fn hasher(&self) -> &H {
        &self.hasher
    }

    #[inline]
    fn view(&self, t: usize, code: u32) -> BucketView<'_> {
        BucketView::new(self.tables[t].get(code), &[])
    }
}

/// One table of the sealed layout: a CSR arena (sorted code index +
/// offsets + one contiguous id slab) plus a small delta overlay for
/// post-seal mutation.
///
/// *Probe path*: `slot_of[code]` (direct index, K ≤ 12) or a binary search
/// of `codes`, then one offset lookup into the slab — a cache-linear read
/// of the whole bucket, vs two pointer chases through `Vec<Vec<u32>>`.
///
/// *Mutation*: live inserts refill arena slack first and spill to the
/// overlay only when a slot is full (or absent); removals replay
/// `Vec::swap_remove` on the *effective* bucket (arena live prefix ++
/// overlay), so the sealed layout stays element-for-element identical to
/// the Vec layout under any mutation sequence — the draw-for-draw
/// guarantee. Invariant: a code with overlay entries has a full arena slot
/// (or none), because inserts prefer arena slack.
#[derive(Clone)]
struct SealedTable {
    /// code → slot for K ≤ 12 (u32::MAX = no slot); empty when the
    /// binary-searched `codes` index is used instead.
    slot_of: Vec<u32>,
    /// slot → code, ascending (the sorted code index).
    codes: Vec<u32>,
    /// Arena offsets per slot (`codes.len() + 1` entries).
    offsets: Vec<u32>,
    /// Live prefix length of each slot (≤ sealed capacity; removals shrink
    /// it, re-inserts refill it before anything spills to the overlay).
    live: Vec<u32>,
    /// The contiguous id slab.
    ids: Vec<u32>,
    /// Delta overlay (BTreeMap for deterministic iteration).
    overlay: BTreeMap<u32, Vec<u32>>,
}

impl SealedTable {
    fn seal(k: usize, buckets: &Buckets) -> SealedTable {
        let sorted = buckets.sorted_buckets();
        let mut codes = Vec::with_capacity(sorted.len());
        let mut offsets = Vec::with_capacity(sorted.len() + 1);
        let mut live = Vec::with_capacity(sorted.len());
        let mut ids = Vec::new();
        offsets.push(0u32);
        for (code, bucket) in &sorted {
            codes.push(*code);
            ids.extend_from_slice(bucket);
            live.push(bucket.len() as u32);
            offsets.push(ids.len() as u32);
        }
        let mut t = SealedTable {
            slot_of: if k <= 12 { vec![u32::MAX; 1 << k] } else { Vec::new() },
            codes,
            offsets,
            live,
            ids,
            overlay: BTreeMap::new(),
        };
        t.rebuild_slot_of();
        t
    }

    fn rebuild_slot_of(&mut self) {
        if self.slot_of.is_empty() {
            return;
        }
        self.slot_of.iter_mut().for_each(|s| *s = u32::MAX);
        for (s, &code) in self.codes.iter().enumerate() {
            self.slot_of[code as usize] = s as u32;
        }
    }

    #[inline]
    fn slot(&self, code: u32) -> Option<usize> {
        if !self.slot_of.is_empty() {
            match self.slot_of.get(code as usize) {
                Some(&s) if s != u32::MAX => Some(s as usize),
                _ => None,
            }
        } else {
            self.codes.binary_search(&code).ok()
        }
    }

    #[inline]
    fn view(&self, code: u32) -> BucketView<'_> {
        let head = match self.slot(code) {
            Some(s) => {
                let off = self.offsets[s] as usize;
                &self.ids[off..off + self.live[s] as usize]
            }
            None => &[],
        };
        let tail = self.overlay.get(&code).map(|v| v.as_slice()).unwrap_or(&[]);
        BucketView::new(head, tail)
    }

    fn push(&mut self, code: u32, id: u32) {
        if let Some(s) = self.slot(code) {
            let cap = (self.offsets[s + 1] - self.offsets[s]) as usize;
            let live = self.live[s] as usize;
            if live < cap {
                debug_assert!(
                    !self.overlay.contains_key(&code),
                    "arena slack with a live overlay breaks Vec-order emulation"
                );
                self.ids[self.offsets[s] as usize + live] = id;
                self.live[s] += 1;
                return;
            }
        }
        self.overlay.entry(code).or_default().push(id);
    }

    /// `Vec::swap_remove` on the effective bucket (arena ++ overlay).
    fn remove_id(&mut self, code: u32, id: u32) -> bool {
        if let Some(s) = self.slot(code) {
            let off = self.offsets[s] as usize;
            let live = self.live[s] as usize;
            if let Some(p) = self.ids[off..off + live].iter().position(|&v| v == id) {
                if let Some(tail) = self.overlay.get_mut(&code) {
                    // overlay non-empty ⇒ arena full: the effective last
                    // element lives in the overlay; move it into the hole
                    let last = tail.pop().expect("overlay vecs are never empty");
                    if tail.is_empty() {
                        self.overlay.remove(&code);
                    }
                    self.ids[off + p] = last;
                } else {
                    self.ids.swap(off + p, off + live - 1);
                    self.live[s] -= 1;
                }
                return true;
            }
        }
        if let Some(tail) = self.overlay.get_mut(&code) {
            if let Some(q) = tail.iter().position(|&v| v == id) {
                tail.swap_remove(q);
                if tail.is_empty() {
                    self.overlay.remove(&code);
                }
                return true;
            }
        }
        false
    }

    /// Fold the overlay (and any removal slack) back into a fresh arena.
    /// Effective bucket order is preserved, so draws are unchanged.
    fn compact(&mut self) {
        let mut buckets: Vec<(u32, Vec<u32>)> = Vec::with_capacity(self.codes.len());
        let mut overlay = std::mem::take(&mut self.overlay);
        for (s, &code) in self.codes.iter().enumerate() {
            let off = self.offsets[s] as usize;
            let mut v = self.ids[off..off + self.live[s] as usize].to_vec();
            if let Some(tail) = overlay.remove(&code) {
                v.extend(tail);
            }
            if !v.is_empty() {
                buckets.push((code, v));
            }
        }
        buckets.extend(overlay);
        buckets.sort_unstable_by_key(|(c, _)| *c);
        self.codes.clear();
        self.offsets.clear();
        self.live.clear();
        self.ids.clear();
        self.offsets.push(0);
        for (code, v) in &buckets {
            self.codes.push(*code);
            self.ids.extend_from_slice(v);
            self.live.push(v.len() as u32);
            self.offsets.push(self.ids.len() as u32);
        }
        self.rebuild_slot_of();
    }

    /// Effective non-empty buckets: arena slots with a live prefix plus
    /// overlay-only codes. O(non-empty + overlay).
    fn for_each_bucket(&self, mut f: impl FnMut(usize)) -> usize {
        let mut non_empty = 0usize;
        for (s, &code) in self.codes.iter().enumerate() {
            let n = self.live[s] as usize + self.overlay.get(&code).map(|v| v.len()).unwrap_or(0);
            if n > 0 {
                non_empty += 1;
                f(n);
            }
        }
        for (&code, tail) in &self.overlay {
            if self.slot(code).is_none() {
                non_empty += 1;
                f(tail.len());
            }
        }
        non_empty
    }

    fn overlay_ids(&self) -> usize {
        self.overlay.values().map(|v| v.len()).sum()
    }
}

/// The sealed (K, L) structure: every table flattened into a CSR bucket
/// arena for O(1)-probe, cache-linear reads on the Algorithm-1 draw path,
/// with a delta overlay absorbing live mutation (see [`SealedTable`]).
/// Produced by [`LshTables::seal`]; [`Self::compact`] folds the overlay
/// back into a fresh arena (the shard set calls it after rebalancing).
#[derive(Clone)]
pub struct SealedTables<H: SrpHasher> {
    hasher: H,
    tables: Vec<SealedTable>,
    len: usize,
}

impl<H: SrpHasher> SealedTables<H> {
    /// Number of points stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no points are stored.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The wrapped hasher.
    pub fn hasher(&self) -> &H {
        &self.hasher
    }

    /// Insert a point id into every table (lands in arena slack or the
    /// delta overlay — same observable bucket sequence as the Vec layout).
    pub fn insert(&mut self, id: u32, x: &[f32]) -> Result<()> {
        if x.len() != self.hasher.dim() {
            return Err(Error::Lsh(format!(
                "insert dim {} into hasher dim {}",
                x.len(),
                self.hasher.dim()
            )));
        }
        for t in 0..self.tables.len() {
            let code = self.hasher.code(t, x);
            self.tables[t].push(code, id);
        }
        self.len += 1;
        Ok(())
    }

    /// Remove a point id (requires the vector it was inserted with).
    /// Returns true if found in all tables — same contract as
    /// [`LshTables::remove`].
    pub fn remove(&mut self, id: u32, x: &[f32]) -> bool {
        let mut found_everywhere = true;
        for t in 0..self.tables.len() {
            let code = self.hasher.code(t, x);
            if !self.tables[t].remove_id(code, id) {
                found_everywhere = false;
            }
        }
        if found_everywhere && self.len > 0 {
            self.len -= 1;
        }
        found_everywhere
    }

    /// Fold every table's overlay back into its arena (post-rebalance
    /// compaction). Bucket order — and therefore the draw stream — is
    /// unchanged.
    pub fn compact(&mut self) {
        for t in self.tables.iter_mut() {
            t.compact();
        }
    }

    /// Total ids currently living in delta overlays (diagnostics; 0 right
    /// after `seal()`/`compact()`).
    pub fn overlay_len(&self) -> usize {
        self.tables.iter().map(|t| t.overlay_ids()).sum()
    }

    /// The bucket matching the query in table `t`.
    pub fn query_bucket(&self, t: usize, query: &[f32]) -> BucketView<'_> {
        let code = self.hasher.code(t, query);
        self.tables[t].view(code)
    }

    /// Occupancy statistics — one O(non-empty) walk per table, like the
    /// Vec layout (cheap enough to sample inside the training loop).
    pub fn stats(&self) -> TableStats {
        let mut buckets = 0usize;
        let mut total = 0usize;
        let mut max_bucket = 0usize;
        let key_space = (1u64 << self.hasher.k()) as f64;
        let mut occupancy_sum = 0.0f64;
        for t in &self.tables {
            let non_empty = t.for_each_bucket(|n| {
                total += n;
                max_bucket = max_bucket.max(n);
            });
            buckets += non_empty;
            occupancy_sum += non_empty as f64 / key_space;
        }
        let occupancy = if self.tables.is_empty() {
            0.0
        } else {
            occupancy_sum / self.tables.len() as f64
        };
        TableStats {
            buckets,
            mean_bucket: if buckets == 0 { 0.0 } else { total as f64 / buckets as f64 },
            max_bucket,
            occupancy,
        }
    }
}

impl<H: SrpHasher> BucketRead for SealedTables<H> {
    type H = H;

    fn hasher(&self) -> &H {
        &self.hasher
    }

    #[inline]
    fn view(&self, t: usize, code: u32) -> BucketView<'_> {
        self.tables[t].view(code)
    }
}

/// One sealed table flattened for the snapshot store: the CSR arena
/// sections exactly as they sit in memory (sorted code index, offsets,
/// live prefixes, id slab) plus the delta overlay as `(code, ids)` pairs in
/// ascending code order. `slot_of` is derived state and is rebuilt on load.
pub(crate) struct SealedTableDump {
    pub(crate) codes: Vec<u32>,
    pub(crate) offsets: Vec<u32>,
    pub(crate) live: Vec<u32>,
    pub(crate) ids: Vec<u32>,
    pub(crate) overlay: Vec<(u32, Vec<u32>)>,
}

/// Borrowed twin of [`SealedTableDump`] — what the *encoder* walks. The
/// arena sections are handed out as slices straight off the live table, so
/// a save never deep-clones the id slab (only the tiny per-bucket index
/// vectors are allocated).
pub(crate) struct SealedTableView<'a> {
    pub(crate) codes: &'a [u32],
    pub(crate) offsets: &'a [u32],
    pub(crate) live: &'a [u32],
    pub(crate) ids: &'a [u32],
    pub(crate) overlay: Vec<(u32, &'a [u32])>,
}

/// Layout-tagged snapshot image of a [`TableStore`]. The Vec layout dumps
/// each table's non-empty buckets in ascending code order with bucket
/// contents *in exact element order* (in-bucket order is part of the draw
/// stream); the sealed layout dumps the already-flat arena section by
/// section — no re-serialization bucket by bucket. The owned form is what
/// the *decoder* produces; encoding goes through the borrowed
/// [`TableDumpView`] so saves do not clone bucket contents.
pub(crate) enum TableDump {
    /// Vec-of-Vec buckets: per table, `(code, ids)` ascending by code.
    Vec { tables: Vec<Vec<(u32, Vec<u32>)>>, len: usize },
    /// CSR arena + overlay per table.
    Sealed { tables: Vec<SealedTableDump>, len: usize },
}

/// Borrowed twin of [`TableDump`] for the encode path.
pub(crate) enum TableDumpView<'a> {
    /// Vec-of-Vec buckets, borrowed in ascending code order.
    Vec { tables: Vec<Vec<(u32, &'a [u32])>>, len: usize },
    /// CSR arena + overlay per table, borrowed.
    Sealed { tables: Vec<SealedTableView<'a>>, len: usize },
}

impl SealedTable {
    fn dump_view(&self) -> SealedTableView<'_> {
        SealedTableView {
            codes: &self.codes,
            offsets: &self.offsets,
            live: &self.live,
            ids: &self.ids,
            overlay: self.overlay.iter().map(|(c, v)| (*c, v.as_slice())).collect(),
        }
    }

    /// Rebuild from a dump. Every structural invariant the probe path
    /// relies on is re-validated here — including that every *live* id
    /// (arena live prefixes + overlay; dead slack entries are never read)
    /// indexes a stored point below `points` — so a snapshot that passed
    /// its CRC but is semantically inconsistent still fails loudly instead
    /// of producing out-of-bounds slab or row reads.
    fn from_dump(k: usize, points: usize, d: SealedTableDump) -> Result<SealedTable> {
        let corrupt = |m: String| Error::Store(format!("sealed table dump: {m}"));
        if d.offsets.len() != d.codes.len() + 1 || d.live.len() != d.codes.len() {
            return Err(corrupt("index section lengths disagree".into()));
        }
        if d.offsets.first() != Some(&0) || *d.offsets.last().unwrap() as usize != d.ids.len() {
            return Err(corrupt("offsets do not span the id slab".into()));
        }
        let cap = 1u64 << k.min(32);
        for s in 0..d.codes.len() {
            if s + 1 < d.codes.len() && d.codes[s] >= d.codes[s + 1] {
                return Err(corrupt("code index is not strictly ascending".into()));
            }
            if (d.codes[s] as u64) >= cap {
                return Err(corrupt(format!("code {} exceeds the 2^{k} key space", d.codes[s])));
            }
            if d.offsets[s] > d.offsets[s + 1] {
                return Err(corrupt("offsets are not monotone".into()));
            }
            if d.live[s] > d.offsets[s + 1] - d.offsets[s] {
                return Err(corrupt(format!("slot {s} live prefix exceeds its capacity")));
            }
            let off = d.offsets[s] as usize;
            for &id in &d.ids[off..off + d.live[s] as usize] {
                if id as usize >= points {
                    return Err(corrupt(format!(
                        "slot {s} holds id {id} but the table stores {points} points"
                    )));
                }
            }
        }
        let mut overlay = BTreeMap::new();
        for (code, ids) in d.overlay {
            if (code as u64) >= cap {
                return Err(corrupt(format!("overlay code {code} exceeds the key space")));
            }
            if ids.is_empty() {
                return Err(corrupt(format!("overlay bucket {code} is empty")));
            }
            if let Some(&id) = ids.iter().find(|&&id| id as usize >= points) {
                return Err(corrupt(format!(
                    "overlay bucket {code} holds id {id} but the table stores {points} points"
                )));
            }
            if overlay.insert(code, ids).is_some() {
                return Err(corrupt(format!("duplicate overlay bucket {code}")));
            }
        }
        let mut t = SealedTable {
            slot_of: if k <= 12 { vec![u32::MAX; 1 << k] } else { Vec::new() },
            codes: d.codes,
            offsets: d.offsets,
            live: d.live,
            ids: d.ids,
            overlay,
        };
        t.rebuild_slot_of();
        Ok(t)
    }
}

/// Either table layout behind one API — the field type of
/// [`crate::coordinator::pipeline::ShardTables`] and the estimators, so the
/// `lsh.sealed` knob can swap layouts without touching the draw logic.
/// `Clone` (requiring `H: Clone`, like every hash family) supports the
/// copy-on-write generation flips of [`crate::runtime::serving`].
#[derive(Clone)]
pub enum TableStore<H: SrpHasher> {
    /// Vec-of-Vec buckets — the mutable build layout.
    Vec(LshTables<H>),
    /// CSR bucket arena + delta overlay — the draw-optimised layout.
    Sealed(SealedTables<H>),
}

impl<H: SrpHasher> TableStore<H> {
    /// Seal a Vec-backed store into the CSR arena (no-op when already
    /// sealed).
    pub fn seal(self) -> Self {
        match self {
            TableStore::Vec(t) => TableStore::Sealed(t.seal()),
            sealed => sealed,
        }
    }

    /// Is this the sealed layout?
    pub fn is_sealed(&self) -> bool {
        matches!(self, TableStore::Sealed(_))
    }

    /// Number of points stored.
    pub fn len(&self) -> usize {
        match self {
            TableStore::Vec(t) => t.len(),
            TableStore::Sealed(t) => t.len(),
        }
    }

    /// True if no points are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert a point id with its vector into every table.
    pub fn insert(&mut self, id: u32, x: &[f32]) -> Result<()> {
        match self {
            TableStore::Vec(t) => t.insert(id, x),
            TableStore::Sealed(t) => t.insert(id, x),
        }
    }

    /// Remove a point id. Returns true if found in all tables.
    pub fn remove(&mut self, id: u32, x: &[f32]) -> bool {
        match self {
            TableStore::Vec(t) => t.remove(id, x),
            TableStore::Sealed(t) => t.remove(id, x),
        }
    }

    /// Fold overlays back into the arena (no-op for the Vec layout).
    pub fn compact(&mut self) {
        if let TableStore::Sealed(t) = self {
            t.compact();
        }
    }

    /// Ids currently living in delta overlays (0 for the Vec layout and
    /// for a freshly sealed/compacted arena).
    pub fn overlay_len(&self) -> usize {
        match self {
            TableStore::Vec(_) => 0,
            TableStore::Sealed(t) => t.overlay_len(),
        }
    }

    /// Occupancy statistics.
    pub fn stats(&self) -> TableStats {
        match self {
            TableStore::Vec(t) => t.stats(),
            TableStore::Sealed(t) => t.stats(),
        }
    }

    /// The bucket in table `t` under an explicit (shared, precomputed)
    /// code — the estimator↔shard contract: the estimator hashes the query
    /// once and every shard probes through this.
    #[inline]
    pub fn query_bucket_coded(&self, t: usize, code: u32) -> BucketView<'_> {
        self.view(t, code)
    }

    /// The bucket matching `query` in table `t` (hashes the query for that
    /// table — tests/diagnostics; the draw path uses precomputed codes).
    pub fn query_bucket(&self, t: usize, query: &[f32]) -> BucketView<'_> {
        let code = self.hasher().code(t, query);
        self.view(t, code)
    }

    /// Borrowed snapshot image of this store (layout-preserving; see
    /// [`TableDumpView`]). No bucket contents are cloned — the encoder
    /// streams straight off the live structures.
    pub(crate) fn dump_view(&self) -> TableDumpView<'_> {
        match self {
            TableStore::Vec(t) => TableDumpView::Vec {
                tables: t.tables.iter().map(|b| b.sorted_buckets()).collect(),
                len: t.len,
            },
            TableStore::Sealed(t) => TableDumpView::Sealed {
                tables: t.tables.iter().map(|s| s.dump_view()).collect(),
                len: t.len,
            },
        }
    }

    /// Rebuild a store from a snapshot dump around `hasher` (a clone of the
    /// saved family). Bucket contents are restored element for element, so
    /// the rebuilt store serves the *identical* draw stream; all structural
    /// invariants — including that every bucket id addresses one of the
    /// `len` stored points — are re-validated and violations are
    /// `Error::Store`, never an out-of-bounds read later on the draw path.
    pub(crate) fn from_dump(hasher: H, dump: TableDump) -> Result<TableStore<H>> {
        let (l, k) = (hasher.l(), hasher.k());
        let cap = 1u64 << k.min(32);
        match dump {
            TableDump::Vec { tables, len } => {
                if tables.len() != l {
                    return Err(Error::Store(format!(
                        "vec table dump has {} tables, hasher family has {l}",
                        tables.len()
                    )));
                }
                let mut t = LshTables::new(hasher);
                for (ti, buckets) in tables.into_iter().enumerate() {
                    let mut prev: Option<u32> = None;
                    for (code, ids) in buckets {
                        if (code as u64) >= cap {
                            return Err(Error::Store(format!(
                                "table {ti}: bucket code {code} exceeds the 2^{k} key space"
                            )));
                        }
                        if prev.map(|p| code <= p).unwrap_or(false) {
                            return Err(Error::Store(format!(
                                "table {ti}: bucket codes not strictly ascending"
                            )));
                        }
                        prev = Some(code);
                        for id in ids {
                            if id as usize >= len {
                                return Err(Error::Store(format!(
                                    "table {ti} code {code}: id {id} but the store holds \
                                     {len} points"
                                )));
                            }
                            t.insert_coded(ti, code, id);
                        }
                    }
                }
                t.finish_coded_inserts(len);
                Ok(TableStore::Vec(t))
            }
            TableDump::Sealed { tables, len } => {
                if tables.len() != l {
                    return Err(Error::Store(format!(
                        "sealed table dump has {} tables, hasher family has {l}",
                        tables.len()
                    )));
                }
                let rebuilt = tables
                    .into_iter()
                    .map(|d| SealedTable::from_dump(k, len, d))
                    .collect::<Result<Vec<_>>>()?;
                Ok(TableStore::Sealed(SealedTables { hasher, tables: rebuilt, len }))
            }
        }
    }
}

impl<H: SrpHasher> BucketRead for TableStore<H> {
    type H = H;

    fn hasher(&self) -> &H {
        match self {
            TableStore::Vec(t) => t.hasher(),
            TableStore::Sealed(t) => t.hasher(),
        }
    }

    #[inline]
    fn view(&self, t: usize, code: u32) -> BucketView<'_> {
        match self {
            TableStore::Vec(inner) => inner.view(t, code),
            TableStore::Sealed(inner) => inner.view(t, code),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::{Pcg64, Rng};
    use crate::lsh::srp::DenseSrp;

    fn unit_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|_| {
                let mut v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                crate::core::matrix::normalize(&mut v);
                v
            })
            .collect()
    }

    #[test]
    fn every_point_lands_in_every_table() {
        let rows = unit_rows(50, 8, 1);
        let h = DenseSrp::new(8, 4, 6, 2);
        let t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        assert_eq!(t.len(), 50);
        let s = t.stats();
        // all 50 ids per table
        let total: usize = (0..6)
            .map(|ti| {
                (0..(1u32 << 4)).map(|c| t.bucket(ti, c).len()).sum::<usize>()
            })
            .sum();
        assert_eq!(total, 50 * 6);
        assert!(s.max_bucket >= 1);
        assert!(s.occupancy > 0.0 && s.occupancy <= 1.0);
    }

    #[test]
    fn query_self_always_finds_self() {
        let rows = unit_rows(30, 12, 3);
        let h = DenseSrp::new(12, 5, 8, 4);
        let t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        for (i, r) in rows.iter().enumerate() {
            for ti in 0..8 {
                let b = t.query_bucket(ti, r);
                assert!(b.contains(&(i as u32)), "point {i} missing from its own bucket");
            }
        }
    }

    #[test]
    fn insert_remove_roundtrip() {
        let rows = unit_rows(20, 6, 5);
        let h = DenseSrp::new(6, 3, 4, 6);
        let mut t = LshTables::new(h);
        for (i, r) in rows.iter().enumerate() {
            t.insert(i as u32, r).unwrap();
        }
        assert_eq!(t.len(), 20);
        assert!(t.remove(7, &rows[7]));
        assert_eq!(t.len(), 19);
        for ti in 0..4 {
            assert!(!t.query_bucket(ti, &rows[7]).contains(&7));
        }
        // removing again fails cleanly
        assert!(!t.remove(7, &rows[7]));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let h = DenseSrp::new(6, 3, 2, 1);
        let mut t = LshTables::new(h);
        assert!(t.insert(0, &[1.0; 5]).is_err());
    }

    /// `remove` + re-`insert` round-trip: bucket membership, `len()` and
    /// `stats()` all identical to a fresh build of the same rows. (Bucket
    /// *order* may differ — removal swap-removes and re-insertion appends —
    /// so membership is compared as sorted sets.)
    #[test]
    fn remove_reinsert_roundtrip_matches_fresh_build() {
        let rows = unit_rows(40, 8, 21);
        let h = DenseSrp::new(8, 4, 6, 22);
        let fresh = LshTables::build(h.clone(), rows.iter().map(|r| r.as_slice())).unwrap();
        let mut t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        for &id in &[3u32, 17, 39, 0] {
            assert!(t.remove(id, &rows[id as usize]));
        }
        assert_eq!(t.len(), 36);
        for &id in &[0u32, 39, 17, 3] {
            t.insert(id, &rows[id as usize]).unwrap();
        }
        assert_eq!(t.len(), fresh.len());
        assert_eq!(t.stats(), fresh.stats());
        for ti in 0..6 {
            for code in 0..(1u32 << 4) {
                let mut a = fresh.bucket(ti, code).to_vec();
                let mut b = t.bucket(ti, code).to_vec();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "table {ti} code {code}");
            }
        }
    }

    /// Property form of the round-trip over random shapes and removal
    /// sets, including the empty-removal and remove-everything cases.
    #[test]
    fn prop_remove_reinsert_roundtrip() {
        use crate::testkit::{gen, prop};
        prop(25, |rng| {
            let n = gen::size(rng, 1, 60);
            let d = gen::size(rng, 3, 10);
            let k = gen::size(rng, 2, 5);
            let l = gen::size(rng, 2, 8);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| gen::unit_vec(rng, d)).collect();
            let h = DenseSrp::new(d, k, l, rng.next_u64());
            let fresh = LshTables::build(h.clone(), rows.iter().map(|r| r.as_slice())).unwrap();
            let mut t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
            let kill: Vec<u32> = (0..n as u32).filter(|_| rng.bernoulli(0.4)).collect();
            for &id in &kill {
                assert!(t.remove(id, &rows[id as usize]));
            }
            assert_eq!(t.len(), n - kill.len());
            if let Some(&id) = kill.first() {
                assert!(!t.remove(id, &rows[id as usize]), "double remove must fail");
                assert_eq!(t.len(), n - kill.len(), "failed remove must not change len");
            }
            for &id in kill.iter().rev() {
                t.insert(id, &rows[id as usize]).unwrap();
            }
            assert_eq!(t.len(), fresh.len());
            assert_eq!(t.stats(), fresh.stats());
            for ti in 0..l {
                for code in 0..(1u32 << k) {
                    let mut a = fresh.bucket(ti, code as u32).to_vec();
                    let mut b = t.bucket(ti, code as u32).to_vec();
                    a.sort_unstable();
                    b.sort_unstable();
                    assert_eq!(a, b, "table {ti} code {code}");
                }
            }
        });
    }

    #[test]
    fn candidate_union_dedups_and_contains_near() {
        let rows = unit_rows(40, 10, 7);
        let h = DenseSrp::new(10, 3, 12, 8);
        let t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        let cands = t.candidate_union(&rows[3]);
        // the point itself must be a candidate (collides with itself in all tables)
        assert!(cands.contains(&3));
        let mut d = cands.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), cands.len(), "union must be deduplicated");
    }

    #[test]
    fn rebuild_replaces_contents() {
        let rows = unit_rows(10, 6, 9);
        let rows2 = unit_rows(15, 6, 10);
        let h = DenseSrp::new(6, 3, 4, 11);
        let mut t = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        t.rebuild(rows2.iter().map(|r| r.as_slice())).unwrap();
        assert_eq!(t.len(), 15);
        for ti in 0..4 {
            let b = t.query_bucket(ti, &rows2[14]);
            assert!(b.contains(&14));
        }
    }

    /// Dense occupancy index: `non_empty` (and therefore `stats()`) stays
    /// exact through interleaved inserts/removes — the incremental counter
    /// must match a from-scratch recount at every step.
    #[test]
    fn prop_dense_occupancy_index_matches_recount() {
        use crate::testkit::{gen, prop};
        prop(25, |rng| {
            let k = gen::size(rng, 2, 6);
            let mut b = Buckets::new(k);
            let mut reference: std::collections::HashMap<u32, Vec<u32>> =
                std::collections::HashMap::new();
            for id in 0..60u32 {
                let code = rng.index(1 << k) as u32;
                b.push(code, id);
                reference.entry(code).or_default().push(id);
            }
            for id in 0..60u32 {
                if rng.bernoulli(0.5) {
                    let code = *reference
                        .iter()
                        .find(|(_, v)| v.contains(&id))
                        .map(|(c, _)| c)
                        .unwrap();
                    assert!(b.remove_id(code, id));
                    let v = reference.get_mut(&code).unwrap();
                    v.retain(|&x| x != id);
                    if v.is_empty() {
                        reference.remove(&code);
                    }
                }
                assert_eq!(b.non_empty(), reference.len(), "occupancy counter drifted");
            }
            let mut walked = 0usize;
            b.for_each_bucket(|bucket| {
                assert!(!bucket.is_empty(), "for_each_bucket visited an empty slot");
                walked += 1;
            });
            assert_eq!(walked, reference.len());
        });
    }

    /// `seal()` preserves every bucket's exact content order, and the
    /// sealed `stats()` agree with the Vec layout's.
    #[test]
    fn seal_preserves_buckets_and_stats() {
        let rows = unit_rows(80, 10, 31);
        let h = DenseSrp::new(10, 4, 7, 32);
        let t = LshTables::build(h.clone(), rows.iter().map(|r| r.as_slice())).unwrap();
        let sealed = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap().seal();
        assert_eq!(sealed.len(), t.len());
        assert_eq!(sealed.overlay_len(), 0);
        assert_eq!(sealed.stats(), t.stats());
        for ti in 0..7 {
            for code in 0..(1u32 << 4) {
                assert_eq!(
                    sealed.view(ti, code).to_vec(),
                    t.bucket(ti, code).to_vec(),
                    "table {ti} code {code}: sealed bucket diverged"
                );
            }
        }
    }

    /// The delta overlay replays `Vec::swap_remove` semantics exactly:
    /// after any interleaving of inserts and removes, every sealed bucket
    /// equals the Vec-layout bucket *element for element* (order included —
    /// the draw-for-draw requirement), and compaction at a random point
    /// changes nothing but drains the overlay.
    #[test]
    fn prop_sealed_mutation_matches_vec_layout_exactly() {
        use crate::testkit::{gen, prop};
        prop(20, |rng| {
            let n = gen::size(rng, 10, 50);
            let d = gen::size(rng, 4, 8);
            let k = gen::size(rng, 2, 4);
            let l = gen::size(rng, 2, 6);
            let rows: Vec<Vec<f32>> = (0..2 * n).map(|_| gen::unit_vec(rng, d)).collect();
            let h = DenseSrp::new(d, k, l, rng.next_u64());
            let mut vecs =
                LshTables::build(h.clone(), rows[..n].iter().map(|r| r.as_slice())).unwrap();
            let mut sealed =
                LshTables::build(h, rows[..n].iter().map(|r| r.as_slice())).unwrap().seal();
            let mut present: Vec<u32> = (0..n as u32).collect();
            let mut absent: Vec<u32> = (n as u32..2 * n as u32).collect();
            for step in 0..40 {
                let do_insert = present.is_empty() || (!absent.is_empty() && rng.bernoulli(0.5));
                if do_insert {
                    let id = absent.swap_remove(rng.index(absent.len()));
                    vecs.insert(id, &rows[id as usize]).unwrap();
                    sealed.insert(id, &rows[id as usize]).unwrap();
                    present.push(id);
                } else {
                    let id = present.swap_remove(rng.index(present.len()));
                    assert!(vecs.remove(id, &rows[id as usize]));
                    assert!(sealed.remove(id, &rows[id as usize]));
                    absent.push(id);
                }
                if step == 20 {
                    sealed.compact();
                    assert_eq!(sealed.overlay_len(), 0, "compact must drain the overlay");
                }
                assert_eq!(sealed.len(), vecs.len());
                for ti in 0..l {
                    for code in 0..(1u32 << k) {
                        assert_eq!(
                            sealed.view(ti, code).to_vec(),
                            vecs.bucket(ti, code).to_vec(),
                            "step {step} table {ti} code {code}: order diverged"
                        );
                    }
                }
            }
            assert_eq!(sealed.stats(), vecs.stats());
        });
    }

    /// Test-only materialisation of a borrowed dump view into the owned
    /// form the decoder produces (the encode path never does this).
    fn owned_dump(view: TableDumpView<'_>) -> TableDump {
        match view {
            TableDumpView::Vec { tables, len } => TableDump::Vec {
                tables: tables
                    .into_iter()
                    .map(|b| b.into_iter().map(|(c, ids)| (c, ids.to_vec())).collect())
                    .collect(),
                len,
            },
            TableDumpView::Sealed { tables, len } => TableDump::Sealed {
                tables: tables
                    .into_iter()
                    .map(|t| SealedTableDump {
                        codes: t.codes.to_vec(),
                        offsets: t.offsets.to_vec(),
                        live: t.live.to_vec(),
                        ids: t.ids.to_vec(),
                        overlay: t.overlay.into_iter().map(|(c, v)| (c, v.to_vec())).collect(),
                    })
                    .collect(),
                len,
            },
        }
    }

    /// Snapshot dump → rebuild round-trip: both layouts reproduce every
    /// bucket element for element — including a sealed store with a live
    /// delta overlay and removal slack — and corrupted dumps are rejected.
    #[test]
    fn dump_roundtrip_preserves_buckets_exactly() {
        let rows = unit_rows(60, 8, 91);
        let h = DenseSrp::new(8, 3, 5, 92);
        for sealed in [false, true] {
            let built = LshTables::build(h.clone(), rows.iter().map(|r| r.as_slice())).unwrap();
            let mut store =
                if sealed { TableStore::Sealed(built.seal()) } else { TableStore::Vec(built) };
            // mutate so the sealed side carries overlay entries + slack
            for id in [3u32, 17, 40] {
                assert!(store.remove(id, &rows[id as usize]));
            }
            for id in [40u32, 3, 17] {
                store.insert(id, &rows[id as usize]).unwrap();
            }
            let rebuilt = TableStore::from_dump(h.clone(), owned_dump(store.dump_view())).unwrap();
            assert_eq!(rebuilt.is_sealed(), sealed);
            assert_eq!(rebuilt.len(), store.len());
            assert_eq!(rebuilt.overlay_len(), store.overlay_len());
            for t in 0..5 {
                for code in 0..(1u32 << 3) {
                    assert_eq!(
                        rebuilt.view(t, code).to_vec(),
                        store.view(t, code).to_vec(),
                        "sealed={sealed} table {t} code {code}: dump round-trip diverged"
                    );
                }
            }
            assert_eq!(rebuilt.stats(), store.stats());
        }
        // corrupted dumps fail loudly
        let bad = TableDump::Sealed {
            tables: vec![SealedTableDump {
                codes: vec![1, 1], // not strictly ascending
                offsets: vec![0, 1, 2],
                live: vec![1, 1],
                ids: vec![0, 1],
                overlay: Vec::new(),
            }],
            len: 2,
        };
        let h1 = DenseSrp::new(8, 3, 1, 93);
        assert!(matches!(TableStore::from_dump(h1, bad), Err(Error::Store(_))));
        let bad = TableDump::Vec { tables: vec![vec![(1u32 << 3, vec![0])]], len: 1 };
        let h1 = DenseSrp::new(8, 3, 1, 93);
        assert!(matches!(TableStore::from_dump(h1, bad), Err(Error::Store(_))));
        // a live id past the stored-point count must be rejected at load,
        // not crash the draw path later (Vec, arena live prefix, overlay)
        let bad = TableDump::Vec { tables: vec![vec![(2u32, vec![0, 7])]], len: 5 };
        let h1 = DenseSrp::new(8, 3, 1, 93);
        assert!(matches!(TableStore::from_dump(h1, bad), Err(Error::Store(_))));
        let bad = TableDump::Sealed {
            tables: vec![SealedTableDump {
                codes: vec![2],
                offsets: vec![0, 2],
                live: vec![2],
                ids: vec![0, 9], // 9 >= len 5
                overlay: Vec::new(),
            }],
            len: 5,
        };
        let h1 = DenseSrp::new(8, 3, 1, 93);
        assert!(matches!(TableStore::from_dump(h1, bad), Err(Error::Store(_))));
        let bad = TableDump::Sealed {
            tables: vec![SealedTableDump {
                codes: vec![2],
                offsets: vec![0, 1],
                live: vec![1],
                ids: vec![0],
                overlay: vec![(2, vec![11])], // 11 >= len 5
            }],
            len: 5,
        };
        let h1 = DenseSrp::new(8, 3, 1, 93);
        assert!(matches!(TableStore::from_dump(h1, bad), Err(Error::Store(_))));
    }

    /// TableStore dispatch: seal round-trip, coded probe and mutation all
    /// agree across the two layouts.
    #[test]
    fn table_store_layouts_agree() {
        let rows = unit_rows(40, 8, 51);
        let h = DenseSrp::new(8, 3, 5, 52);
        let built = LshTables::build(h, rows.iter().map(|r| r.as_slice())).unwrap();
        let mut store = TableStore::Vec(built);
        assert!(!store.is_sealed());
        let stats_vec = store.stats();
        store = store.seal();
        assert!(store.is_sealed());
        assert_eq!(store.len(), 40);
        assert_eq!(store.stats(), stats_vec);
        assert!(store.remove(7, &rows[7]));
        store.insert(7, &rows[7]).unwrap();
        store.compact();
        assert_eq!(store.len(), 40);
        let hasher_code = store.hasher().code(2, &rows[3]);
        let v = store.query_bucket_coded(2, hasher_code);
        assert!(v.iter().any(|id| id == 3), "coded probe lost the point's own bucket");
        assert_eq!(v.to_vec(), store.query_bucket(2, &rows[3]).to_vec());
    }
}
