//! Collision-probability formulas and the sampling probability of
//! Algorithm 1.
//!
//! For SimHash the per-bit collision probability between a stored vector
//! `x` and the query `q` is (paper eq. 14)
//!
//! ```text
//! cp(x, q) = 1 − acos( x·q / (‖x‖‖q‖) ) / π
//! ```
//!
//! Algorithm 1 probes uniformly-random tables until it hits a non-empty
//! bucket; if the accepted bucket was found at the `l`-th probe and has size
//! `S`, the probability that a *specific* point `x` was returned is
//!
//! ```text
//! p(x) = cp(x,q)^K · (1 − cp(x,q)^K)^(l−1) · 1/S
//! ```
//!
//! which the LGD estimator inverts for unbiasedness (Thm 1).

use crate::core::matrix::angular_similarity;
use crate::core::numerics::{clamp_prob, normed_cosine, quadratic_angular_cp};

/// SimHash per-bit collision probability (eq. 14), clamped to [ε, 1−ε] so
/// importance weights stay finite even for near-antipodal pairs.
#[inline]
pub fn simhash_cp(x: &[f32], q: &[f32]) -> f64 {
    clamp_prob(angular_similarity(x, q))
}

/// Probability that `x` lands in the same K-bit bucket as the query in one
/// table: `cp^K` (K independent hyperplanes).
#[inline]
pub fn bucket_match_prob(cp: f64, k: usize) -> f64 {
    cp.powi(k as i32)
}

/// Full Algorithm-1 sampling probability: the point matched the bucket of
/// the `l`-th probed table, missed the previous `l−1`, and won the uniform
/// within-bucket draw among `bucket_size` members.
#[inline]
pub fn sampling_probability(cp: f64, k: usize, probes: usize, bucket_size: usize) -> f64 {
    debug_assert!(probes >= 1 && bucket_size >= 1);
    let m = bucket_match_prob(cp, k);
    m * (1.0 - m).powi(probes as i32 - 1) / bucket_size as f64
}

/// Collision probability for the *quadratic* hash space (§2.1): hashing
/// `T(u) = vec(u uᵀ)` makes per-bit collision monotone in `(u·v)²`, i.e. in
/// the absolute inner product. Given raw vectors `u`, `v`, this returns the
/// per-bit cp of their quadratic expansions without materialising them:
/// `cos(T(u), T(v)) = (u·v)² / (‖u‖²‖v‖²)`.
#[inline]
pub fn quadratic_cp(u: &[f32], v: &[f32]) -> f64 {
    use crate::core::matrix::{dot_f64, norm2};
    let nu = norm2(u);
    let nv = norm2(v);
    if nu == 0.0 || nv == 0.0 {
        return 0.5;
    }
    quadratic_angular_cp(normed_cosine(dot_f64(u, v), nu, nv))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cp_is_monotone_in_cosine() {
        // construct pairs with increasing cosine
        let q = [1.0f32, 0.0];
        let angles = [2.8, 2.0, 1.2, 0.6, 0.1f32];
        let mut last = 0.0;
        for &a in &angles {
            let x = [a.cos(), a.sin()];
            let cp = simhash_cp(&x, &q);
            assert!(cp > last, "cp {cp} not increasing");
            last = cp;
        }
    }

    #[test]
    fn cp_bounds() {
        let q = [1.0f32, 0.0];
        assert!(simhash_cp(&[1.0, 0.0], &q) > 0.999);
        assert!(simhash_cp(&[-1.0, 0.0], &q) < 0.001);
        assert!((simhash_cp(&[0.0, 1.0], &q) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn sampling_probability_decomposes() {
        let cp = 0.8;
        let k = 5;
        let m = bucket_match_prob(cp, k);
        assert!((m - 0.8f64.powi(5)).abs() < 1e-12);
        let p1 = sampling_probability(cp, k, 1, 4);
        assert!((p1 - m / 4.0).abs() < 1e-12);
        let p2 = sampling_probability(cp, k, 2, 4);
        assert!((p2 - m * (1.0 - m) / 4.0).abs() < 1e-12);
        assert!(p2 < p1);
    }

    #[test]
    fn sampling_probability_valid_range() {
        for &cp in &[0.01, 0.3, 0.5, 0.9, 0.999] {
            for probes in 1..5 {
                for s in [1usize, 3, 100] {
                    let p = sampling_probability(cp, 5, probes, s);
                    assert!(p > 0.0 && p <= 1.0, "p={p}");
                }
            }
        }
    }

    #[test]
    fn quadratic_cp_monotone_in_abs_inner_product() {
        let u = [1.0f32, 0.0];
        // |cos| equal for ±θ — quadratic map must agree
        let a = [0.6f32.cos(), 0.6f32.sin()];
        let b = [0.6f32.cos(), -(0.6f32.sin())];
        assert!((quadratic_cp(&u, &a) - quadratic_cp(&u, &b)).abs() < 1e-9);
        // larger |inner product| ⇒ larger quadratic cp
        let far = [1.4f32.cos(), 1.4f32.sin()];
        assert!(quadratic_cp(&u, &a) > quadratic_cp(&u, &far));
        // antipodal = identical under the quadratic map
        let neg = [-1.0f32, 0.0];
        assert!(quadratic_cp(&u, &neg) > 0.999);
    }
}
