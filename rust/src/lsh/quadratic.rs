//! The quadratic feature map `T(·)` of §2.1.
//!
//! The optimal weights involve an *absolute* inner product
//! `|[θ,−1]·[x,y]|`, which plain SimHash cannot target (its collision law is
//! monotone in the signed inner product). The paper's fix:
//!
//! ```text
//! |a·b|² = (a·b)² = ⟨vec(a aᵀ), vec(b bᵀ)⟩ = ⟨T(a), T(b)⟩
//! ```
//!
//! so hashing `T(x)` and querying `T(θ)` makes collisions monotone in the
//! absolute inner product (square is monotone on ℝ≥0; composition of
//! monotone maps is monotone).
//!
//! Materialising `T(u) ∈ R^{(d+1)²}` is quadratic in memory, so besides the
//! explicit map (used in tests and for small d) this module provides
//! [`QuadraticSrp`]: an SRP family acting on the *implicit* expansion — each
//! hash bit is `sign(uᵀ M u)` with a sparse random ±1 matrix `M`, costing
//! `nnz(M)` multiply-adds and never forming `T(u)`.

use crate::core::rng::{Pcg64, Rng};
use crate::lsh::srp::{HashStats, SrpHasher};

/// Explicit quadratic expansion `T(u) = vec(u uᵀ)` (row-major).
pub fn expand(u: &[f32]) -> Vec<f32> {
    let d = u.len();
    let mut out = Vec::with_capacity(d * d);
    for i in 0..d {
        for j in 0..d {
            out.push(u[i] * u[j]);
        }
    }
    out
}

/// Inner product in the expanded space, computed implicitly:
/// `⟨T(a), T(b)⟩ = (a·b)²`.
pub fn expanded_inner(a: &[f32], b: &[f32]) -> f64 {
    let ip = crate::core::matrix::dot_f64(a, b);
    ip * ip
}

/// Sparse symmetric-free random ±1 "matrix" acting as one hyperplane in the
/// expanded space: a list of (i, j, sign) entries.
#[derive(Debug, Clone, Default)]
struct SparseQuadPlane {
    ii: Vec<u32>,
    jj: Vec<u32>,
    sign: Vec<f32>,
}

impl SparseQuadPlane {
    #[inline]
    fn form(&self, u: &[f32]) -> f64 {
        let mut s = 0.0f64;
        for t in 0..self.ii.len() {
            s += (self.sign[t] * u[self.ii[t] as usize] * u[self.jj[t] as usize]) as f64;
        }
        s
    }
}

/// SRP over the implicit quadratic expansion: bit = `sign(uᵀ M u)` with
/// sparse ±1 `M`. Equivalent to running [`super::srp::SparseSrp`] on
/// `T(u)` without materialising it.
#[derive(Debug, Clone)]
pub struct QuadraticSrp {
    dim: usize,
    k: usize,
    l: usize,
    density: f64,
    planes: Vec<SparseQuadPlane>,
    counters: std::sync::Arc<crate::lsh::srp::HashCounters>,
}

impl QuadraticSrp {
    /// Fresh family over raw dimension `dim` (expanded dim is `dim²`).
    pub fn new(dim: usize, k: usize, l: usize, density: f64, seed: u64) -> Self {
        assert!(k > 0 && k <= 32);
        assert!(l > 0);
        assert!(density > 0.0 && density <= 1.0);
        let mut rng = Pcg64::new(seed, 0x5150_5f51); // "QP_Q"
        let d2 = dim * dim;
        let expect = ((d2 as f64 * density).ceil() as usize).max(1);
        let mut planes = Vec::with_capacity(l * k);
        for _ in 0..l * k {
            let mut p = SparseQuadPlane::default();
            // Sample expected-count entries (with replacement — duplicates
            // merely double a coefficient, preserving sign-randomness).
            for _ in 0..expect {
                let e = rng.index(d2);
                p.ii.push((e / dim) as u32);
                p.jj.push((e % dim) as u32);
                p.sign.push(if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 });
            }
            planes.push(p);
        }
        QuadraticSrp { dim, k, l, density, planes, counters: Default::default() }
    }

    /// Raw per-plane `(i, j, sign)` entry triples — the snapshot payload
    /// (L·K planes in table-major, bit-minor order).
    pub(crate) fn plane_parts(&self) -> Vec<(&[u32], &[u32], &[f32])> {
        self.planes
            .iter()
            .map(|p| (p.ii.as_slice(), p.jj.as_slice(), p.sign.as_slice()))
            .collect()
    }

    /// Configured nonzero density (diagnostic + snapshot payload).
    pub fn density(&self) -> f64 {
        self.density
    }

    /// Rebuild a family from snapshot parts; bit-exact codes versus the
    /// saved family (the plane entries are the entire hash state).
    pub(crate) fn from_parts(
        dim: usize,
        k: usize,
        l: usize,
        density: f64,
        planes: Vec<(Vec<u32>, Vec<u32>, Vec<f32>)>,
    ) -> crate::core::error::Result<Self> {
        use crate::core::error::Error;
        if k == 0 || k > 32 || l == 0 || dim == 0 || planes.len() != l * k {
            return Err(Error::Store(format!(
                "quadratic hasher parts inconsistent: dim {dim} k {k} l {l} with {} planes",
                planes.len()
            )));
        }
        if !(density > 0.0 && density <= 1.0) {
            return Err(Error::Store(format!("quadratic hasher density {density} out of (0,1]")));
        }
        let mut built = Vec::with_capacity(planes.len());
        for (idx, (ii, jj, sign)) in planes.into_iter().enumerate() {
            if ii.len() != jj.len() || ii.len() != sign.len() || ii.is_empty() {
                return Err(Error::Store(format!("quadratic plane {idx} has ragged entries")));
            }
            if ii.iter().chain(jj.iter()).any(|&v| v as usize >= dim) {
                return Err(Error::Store(format!(
                    "quadratic plane {idx} references a dimension >= {dim}"
                )));
            }
            built.push(SparseQuadPlane { ii, jj, sign });
        }
        Ok(QuadraticSrp { dim, k, l, density, planes: built, counters: Default::default() })
    }
}

impl SrpHasher for QuadraticSrp {
    fn dim(&self) -> usize {
        self.dim
    }
    fn k(&self) -> usize {
        self.k
    }
    fn l(&self) -> usize {
        self.l
    }

    #[inline]
    fn code(&self, table: usize, x: &[f32]) -> u32 {
        debug_assert_eq!(x.len(), self.dim);
        self.counters.code.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let base = table * self.k;
        let mut c = 0u32;
        for b in 0..self.k {
            let s = self.planes[base + b].form(x);
            c = (c << 1) | (s >= 0.0) as u32;
        }
        c
    }

    fn mults_per_code(&self) -> f64 {
        // two multiplies per sparse entry (sign·u_i·u_j)
        2.0 * self.k as f64 * (self.dim * self.dim) as f64 * self.density
    }

    fn hash_stats(&self) -> HashStats {
        self.counters.snapshot()
    }

    fn collision_prob(&self, x: &[f32], q: &[f32]) -> f64 {
        // collision law of the expanded space: monotone in (x·q)², i.e. in
        // the absolute inner product — the paper's T(·) fix for eq. 4
        crate::lsh::collision::quadratic_cp(x, q)
    }

    fn collision_prob_normed(&self, x: &[f32], q: &[f32], nx: f64, nq: f64) -> f64 {
        if nx == 0.0 || nq == 0.0 {
            return 0.5;
        }
        use crate::core::numerics::{dot_fast, normed_cosine, quadratic_angular_cp};
        quadratic_angular_cp(normed_cosine(dot_fast(x, q) as f64, nx, nq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::{dot_f64, normalize};

    #[test]
    fn expand_matches_outer_product() {
        let u = [1.0f32, 2.0, -3.0];
        let t = expand(&u);
        assert_eq!(t.len(), 9);
        assert_eq!(t[0], 1.0); // u0*u0
        assert_eq!(t[1], 2.0); // u0*u1
        assert_eq!(t[5], -6.0); // u1*u2
        assert_eq!(t[8], 9.0); // u2*u2
    }

    #[test]
    fn expanded_inner_is_square_of_inner() {
        let a = [0.5f32, -1.0, 2.0];
        let b = [1.0f32, 3.0, 0.25];
        let explicit = dot_f64(&expand(&a), &expand(&b));
        let implicit = expanded_inner(&a, &b);
        assert!((explicit - implicit).abs() < 1e-6);
        let ip = dot_f64(&a, &b);
        assert!((implicit - ip * ip).abs() < 1e-9);
    }

    #[test]
    fn quadratic_hash_sign_invariant() {
        // T(u) = T(−u): codes must agree for antipodal inputs — exactly the
        // property that makes |inner product| hashable.
        let h = QuadraticSrp::new(8, 5, 6, 0.2, 42);
        let mut rng = Pcg64::seeded(5);
        for _ in 0..20 {
            let mut u: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
            normalize(&mut u);
            let neg: Vec<f32> = u.iter().map(|v| -v).collect();
            for t in 0..6 {
                assert_eq!(h.code(t, &u), h.code(t, &neg), "quadratic hash not sign-invariant");
            }
        }
    }

    /// Collision rate of QuadraticSrp increases with |cos| — the monotone
    /// adaptive-sampling property for the absolute inner product.
    #[test]
    fn quadratic_collisions_monotone_in_abs_cosine() {
        let dim = 10;
        let (k, l) = (1usize, 1500usize);
        let h = QuadraticSrp::new(dim, k, l, 0.3, 9);
        let mut rng = Pcg64::seeded(10);
        let mut x: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
        normalize(&mut x);
        // Build queries at decreasing |cosine| to x.
        let mut rates = Vec::new();
        for &blend in &[0.95f32, 0.6, 0.2] {
            let mut q: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
            normalize(&mut q);
            let mut y: Vec<f32> = (0..dim).map(|i| blend * x[i] + (1.0 - blend) * q[i]).collect();
            normalize(&mut y);
            let hits = (0..l).filter(|&t| h.code(t, &x) == h.code(t, &y)).count();
            rates.push(hits as f64 / l as f64);
        }
        assert!(
            rates[0] > rates[1] && rates[1] > rates[2],
            "collision rates not monotone: {rates:?}"
        );
    }

    #[test]
    fn cost_model_scales_with_density() {
        let a = QuadraticSrp::new(20, 5, 2, 0.1, 1);
        let b = QuadraticSrp::new(20, 5, 2, 0.2, 1);
        assert!(b.mults_per_code() > a.mults_per_code());
    }
}
