//! Signed random projections (SimHash) — dense and sparse variants.
//!
//! A hasher owns `L × K` hyperplanes. Table `t`'s *meta-hash* of `x` is the
//! K-bit code whose bit `b` is `sign(⟨w_{t,b}, x⟩) ≥ 0` (eq. 13 of the
//! paper). Collision probability per bit is `1 − θ/π` (eq. 14), monotone in
//! cosine similarity — the property LGD's monotone-sampling argument needs.
//!
//! The paper's running-time claim (§2.2) relies on *very sparse* random
//! projections (density 1/30, ±1 entries): computing all `K` hash bits then
//! costs `K·d·density ≈ d/6` multiplications — far below the `d`
//! multiplications of a gradient update. [`SparseSrp`] implements exactly
//! that cost model; [`DenseSrp`] is the reference implementation the sparse
//! one is validated against.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::core::error::{Error, Result};
use crate::core::matrix::{dot_f64, Matrix};
use crate::core::numerics::{angular_cp, clamp_prob, dot_fast, normed_cosine};
use crate::core::rng::{Pcg64, Rng};

/// Cumulative hash-invocation counters of a hasher family. The counters
/// are *shared across clones* (the sharded engine clones one family per
/// shard; all clones report into one set), so the estimator-level contract
/// "the query is hashed once per draw regardless of shard count" is
/// directly observable.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HashStats {
    /// Single-table `code()` invocations (per-row hashing path).
    pub code_calls: u64,
    /// Fused whole-query `codes_all` invocations — each computes all `L·K`
    /// projections in one sequential pass.
    pub fused_calls: u64,
}

/// Shared atomic cell behind [`HashStats`] (relaxed counters; clones of a
/// family hold the same `Arc`).
#[derive(Debug, Default)]
pub(crate) struct HashCounters {
    pub(crate) code: AtomicU64,
    pub(crate) fused: AtomicU64,
}

impl HashCounters {
    pub(crate) fn snapshot(&self) -> HashStats {
        HashStats {
            code_calls: self.code.load(Ordering::Relaxed),
            fused_calls: self.fused.load(Ordering::Relaxed),
        }
    }
}

/// A family of `L` K-bit SimHash meta-hash functions over `R^dim`.
pub trait SrpHasher: Send + Sync {
    /// Input dimensionality.
    fn dim(&self) -> usize;
    /// Bits per table (meta-hash width). Must be ≤ 32.
    fn k(&self) -> usize;
    /// Number of tables.
    fn l(&self) -> usize;
    /// K-bit code of `x` under table `t`'s meta-hash.
    fn code(&self, table: usize, x: &[f32]) -> u32;
    /// Expected multiplications to compute one table's K-bit code — the
    /// §2.2 cost model, reported by the sampling benchmarks.
    fn mults_per_code(&self) -> f64;

    /// Multiplication-equivalent work of hashing one query against *all* L
    /// tables. The fused `codes_all` pass does exactly this much arithmetic
    /// (same mults as L independent `code()` calls, one sequential sweep).
    fn mults_all(&self) -> f64 {
        self.l() as f64 * self.mults_per_code()
    }

    /// Hash-invocation counters (shared across clones of this family; see
    /// [`HashStats`]). Families without instrumentation report zeros.
    fn hash_stats(&self) -> HashStats {
        HashStats::default()
    }

    /// Per-bit collision probability between a stored vector and a query
    /// under THIS family's geometry. Linear SimHash families use the
    /// angular law `1 − θ/π` (eq. 14); the quadratic family overrides this
    /// with the law of the expanded space. The Algorithm-1 probability
    /// (and therefore Thm 1's unbiased weights) must use this, not a fixed
    /// formula.
    fn collision_prob(&self, x: &[f32], q: &[f32]) -> f64 {
        crate::lsh::collision::simhash_cp(x, q)
    }

    /// Collision probability given precomputed norms — the hot-path variant
    /// (saves recomputing ‖x‖ and ‖q‖ on every draw). Same law as
    /// [`Self::collision_prob`].
    fn collision_prob_normed(&self, x: &[f32], q: &[f32], nx: f64, nq: f64) -> f64 {
        if nx == 0.0 || nq == 0.0 {
            return 0.5;
        }
        // ONE copy of the cosine/clamp logic: core::numerics owns it (the
        // sparse and quadratic overrides route through the same helpers)
        angular_cp(normed_cosine(dot_fast(x, q) as f64, nx, nq))
    }

    /// Codes for all L tables. The default walks the tables one `code()` at
    /// a time; [`DenseSrp`] and [`SparseSrp`] override it with a *fused*
    /// one-pass sweep (CSC layout over the input dimensions) that performs
    /// the same multiplications with sequential memory access and is
    /// bitwise-identical to the per-table path (tested below). This is the
    /// entry point the estimators use to hash a query once per draw/batch
    /// and share the codes across every shard.
    fn codes_all(&self, x: &[f32], out: &mut Vec<u32>) {
        out.clear();
        for t in 0..self.l() {
            out.push(self.code(t, x));
        }
    }
}

/// Dense SimHash: i.i.d. N(0,1) hyperplanes. Exact collision probability
/// `1 − θ/π` per bit.
#[derive(Debug, Clone)]
pub struct DenseSrp {
    dim: usize,
    k: usize,
    l: usize,
    /// (l*k) × dim plane matrix in aligned lane-padded storage — every
    /// plane row is a `row_block` the kernel layer can run at full width.
    planes: Matrix,
    /// dim × (l*k) transpose of `planes` — the CSC layout the fused
    /// `codes_all` sweep walks sequentially (per input dimension, all L·K
    /// plane coefficients are contiguous), lane-padded like `planes`.
    planes_t: Matrix,
    counters: Arc<HashCounters>,
}

/// Build the dim-major lane-padded transpose of a flat (l·k) × dim plane
/// buffer — one loop shared by `new` and the snapshot restore path, so a
/// restored family's memory layout is identical to the saved one's.
fn transpose_planes(dim: usize, lk: usize, planes: &[f32]) -> Matrix {
    let mut t = Matrix::zeros(dim, lk);
    for r in 0..lk {
        for i in 0..dim {
            t.set(i, r, planes[r * dim + i]);
        }
    }
    t
}

impl DenseSrp {
    /// Draw a fresh family. Panics if `k > 32` or `k == 0`.
    pub fn new(dim: usize, k: usize, l: usize, seed: u64) -> Self {
        assert!(k > 0 && k <= 32, "meta-hash width k={k} must be in 1..=32");
        assert!(l > 0, "need at least one table");
        let mut rng = Pcg64::new(seed, 0x5250_5f44); // "RP_D"
        let mut planes = vec![0.0f32; l * k * dim];
        for v in planes.iter_mut() {
            *v = rng.gaussian() as f32;
        }
        let lk = l * k;
        let planes_t = transpose_planes(dim, lk, &planes);
        let planes = Matrix::from_vec(lk, dim, planes).expect("lk*dim buffer");
        DenseSrp { dim, k, l, planes, planes_t, counters: Arc::default() }
    }

    #[inline]
    fn plane(&self, table: usize, bit: usize) -> &[f32] {
        self.planes.row(table * self.k + bit)
    }

    /// Raw (L·K) × dim plane matrix, logical widths only — the snapshot
    /// payload (the lane padding never reaches disk).
    pub(crate) fn planes_raw(&self) -> Vec<f32> {
        let mut flat = Vec::with_capacity(self.planes.rows() * self.dim);
        for r in 0..self.planes.rows() {
            flat.extend_from_slice(self.planes.row(r));
        }
        flat
    }

    /// Rebuild a family from snapshot parts. The dim-major transpose is
    /// recomputed with the same loop as [`Self::new`], so the restored
    /// family's codes are bitwise-identical to the saved one. Counters
    /// start fresh (a restored index has done no hashing yet).
    pub(crate) fn from_parts(dim: usize, k: usize, l: usize, planes: Vec<f32>) -> Result<Self> {
        if k == 0 || k > 32 || l == 0 || dim == 0 || planes.len() != l * k * dim {
            return Err(Error::Store(format!(
                "dense hasher parts inconsistent: dim {dim} k {k} l {l} with {} plane floats",
                planes.len()
            )));
        }
        let lk = l * k;
        let planes_t = transpose_planes(dim, lk, &planes);
        let planes = Matrix::from_vec(lk, dim, planes).expect("length checked above");
        Ok(DenseSrp { dim, k, l, planes, planes_t, counters: Arc::default() })
    }
}

impl SrpHasher for DenseSrp {
    fn dim(&self) -> usize {
        self.dim
    }
    fn k(&self) -> usize {
        self.k
    }
    fn l(&self) -> usize {
        self.l
    }

    #[inline]
    fn code(&self, table: usize, x: &[f32]) -> u32 {
        debug_assert_eq!(x.len(), self.dim);
        self.counters.code.fetch_add(1, Ordering::Relaxed);
        let mut c = 0u32;
        for b in 0..self.k {
            let s = dot_f64(self.plane(table, b), x);
            c = (c << 1) | (s >= 0.0) as u32;
        }
        c
    }

    fn mults_per_code(&self) -> f64 {
        (self.k * self.dim) as f64
    }

    /// Fused one-pass sweep: one traversal of `x` accumulating all `L·K`
    /// projections against the dim-major transpose, then one bit-pack pass.
    /// Per plane row, the accumulation visits dimensions in the same
    /// ascending order (and with the same f64 ops) as `dot_f64`, so the
    /// codes are bitwise-identical to the per-table `code()` path.
    fn codes_all(&self, x: &[f32], out: &mut Vec<u32>) {
        debug_assert_eq!(x.len(), self.dim);
        self.counters.fused.fetch_add(1, Ordering::Relaxed);
        let lk = self.l * self.k;
        let mut acc = vec![0.0f64; lk];
        for (i, &xi) in x.iter().enumerate() {
            let xi = xi as f64;
            let col = self.planes_t.row(i);
            for (a, &p) in acc.iter_mut().zip(col) {
                *a += p as f64 * xi;
            }
        }
        out.clear();
        for t in 0..self.l {
            let mut c = 0u32;
            for b in 0..self.k {
                c = (c << 1) | (acc[t * self.k + b] >= 0.0) as u32;
            }
            out.push(c);
        }
    }

    fn hash_stats(&self) -> HashStats {
        self.counters.snapshot()
    }
}

/// One sparse ±1 projection row. Entries are `(dim_index << 1) | sign_bit`
/// (sign bit 1 = −1 coefficient) in ascending dimension order — the
/// *canonical* accumulation order shared by [`SparseRow::project`] and the
/// fused CSC sweep of `codes_all`, which makes their floating-point sums
/// (and therefore the codes) bitwise identical.
#[derive(Debug, Clone, Default)]
struct SparseRow {
    entries: Vec<u32>,
}

impl SparseRow {
    #[inline]
    fn push(&mut self, dim_index: u32, neg: bool) {
        self.entries.push((dim_index << 1) | neg as u32);
    }

    #[inline]
    fn project(&self, x: &[f32]) -> f64 {
        let mut s = 0.0f64;
        for &e in &self.entries {
            let v = x[(e >> 1) as usize] as f64;
            if e & 1 == 0 {
                s += v;
            } else {
                s -= v;
            }
        }
        s
    }

    fn nnz(&self) -> usize {
        self.entries.len()
    }
}

/// Calibrated per-bit collision law: `cp` as a function of cosine
/// similarity, measured empirically on the actual plane family.
///
/// Very sparse projections do NOT follow the dense angular law `1 − θ/π`:
/// with ~3 nonzeros per plane the sign statistic is far from Gaussian and
/// the collision probability is strongly compressed toward 1/2. Using the
/// analytic law in Algorithm 1's probability then mis-weights draws by
/// orders of magnitude (see `experiments::variance_ablation`). The curve
/// below is estimated once at construction (synthetic pairs at controlled
/// cosine, counting actual bit agreements over all K·L planes), smoothed to
/// be monotone, and interpolated at query time — an O(1) lookup on top of
/// the O(d) cosine the probability computation already needs.
#[derive(Debug, Clone)]
pub struct CalibCurve {
    /// cp at bin centers over cos ∈ [−1, 1].
    bins: Vec<f64>,
}

impl CalibCurve {
    /// Number of cosine bins.
    pub const BINS: usize = 41;

    /// The raw bin values (snapshot payload).
    pub(crate) fn bins(&self) -> &[f64] {
        &self.bins
    }

    /// Rebuild from snapshot bins.
    pub(crate) fn from_bins(bins: Vec<f64>) -> Result<Self> {
        if bins.len() != Self::BINS {
            return Err(Error::Store(format!(
                "calibration curve has {} bins, expected {}",
                bins.len(),
                Self::BINS
            )));
        }
        Ok(CalibCurve { bins })
    }

    /// Evaluate by linear interpolation, clamped to (0, 1).
    pub fn eval(&self, cos: f64) -> f64 {
        let x = ((cos.clamp(-1.0, 1.0) + 1.0) / 2.0) * (Self::BINS - 1) as f64;
        let lo = x.floor() as usize;
        let hi = (lo + 1).min(Self::BINS - 1);
        let w = x - lo as f64;
        clamp_prob(self.bins[lo] * (1.0 - w) + self.bins[hi] * w)
    }
}

/// Very sparse random projections (Achlioptas / Li-Hastie-Church style):
/// each coefficient is `+1` or `−1` with probability `density/2` each, `0`
/// otherwise. Additions only — no multiplications — which is how the paper
/// gets "d/30 multiplications in expectation for all hashes".
#[derive(Debug, Clone)]
pub struct SparseSrp {
    dim: usize,
    k: usize,
    l: usize,
    density: f64,
    rows: Vec<SparseRow>,
    /// CSC transpose of `rows`: `post[post_off[i]..post_off[i+1]]` lists
    /// the plane rows touching input dimension `i` as
    /// `(row << 1) | sign_bit`. The fused `codes_all` walks this once,
    /// sequentially, accumulating all `L·K` projections.
    post_off: Vec<u32>,
    post: Vec<u32>,
    calib: CalibCurve,
    counters: Arc<HashCounters>,
}

impl SparseSrp {
    /// Draw a fresh sparse family with the given nonzero `density`
    /// (paper default: 1/30). Each row is guaranteed ≥ 1 nonzero so no hash
    /// bit is constant.
    pub fn new(dim: usize, k: usize, l: usize, density: f64, seed: u64) -> Self {
        assert!(k > 0 && k <= 32, "meta-hash width k={k} must be in 1..=32");
        assert!(l > 0, "need at least one table");
        assert!(density > 0.0 && density <= 1.0, "density {density} out of (0,1]");
        let mut rng = Pcg64::new(seed, 0x5250_5f53); // "RP_S"
        let mut rows = Vec::with_capacity(l * k);
        for _ in 0..l * k {
            let mut row = SparseRow::default();
            for i in 0..dim {
                if rng.bernoulli(density) {
                    row.push(i as u32, rng.next_u64() & 1 != 0);
                }
            }
            if row.nnz() == 0 {
                // Force one nonzero so the bit carries signal.
                let i = rng.index(dim) as u32;
                row.push(i, rng.next_u64() & 1 != 0);
            }
            rows.push(row);
        }
        let (post_off, post) = Self::transpose(dim, &rows);
        let mut h = SparseSrp {
            dim,
            k,
            l,
            density,
            rows,
            post_off,
            post,
            calib: CalibCurve { bins: Vec::new() },
            counters: Arc::default(),
        };
        h.calib = h.calibrate(&mut rng);
        h
    }

    /// Build the CSC postings (dimension → plane rows touching it).
    fn transpose(dim: usize, rows: &[SparseRow]) -> (Vec<u32>, Vec<u32>) {
        let mut counts = vec![0u32; dim + 1];
        for row in rows {
            for &e in &row.entries {
                counts[(e >> 1) as usize + 1] += 1;
            }
        }
        for i in 0..dim {
            counts[i + 1] += counts[i];
        }
        let post_off = counts.clone();
        let mut cursor = counts;
        let mut post = vec![0u32; *post_off.last().unwrap_or(&0) as usize];
        for (r, row) in rows.iter().enumerate() {
            for &e in &row.entries {
                let d = (e >> 1) as usize;
                post[cursor[d] as usize] = ((r as u32) << 1) | (e & 1);
                cursor[d] += 1;
            }
        }
        (post_off, post)
    }

    /// Measure this family's per-bit collision law: for each cosine bin,
    /// draw synthetic pairs at that exact cosine and count actual sign
    /// agreements over every plane in the family. A monotone (isotonic)
    /// pass smooths Monte-Carlo noise. One-time cost ~1M adds.
    fn calibrate(&self, rng: &mut Pcg64) -> CalibCurve {
        let bins = CalibCurve::BINS;
        let pairs_per_bin = 12usize;
        let planes = &self.rows;
        let mut curve = vec![0.0f64; bins];
        for b in 0..bins {
            let cos_t = -1.0 + 2.0 * b as f64 / (bins - 1) as f64;
            let mut agree = 0u64;
            let mut total = 0u64;
            for _ in 0..pairs_per_bin {
                // unit u and unit v with <u,v> = cos_t
                let mut u: Vec<f32> = (0..self.dim).map(|_| rng.gaussian() as f32).collect();
                crate::core::matrix::normalize(&mut u);
                let mut w: Vec<f32> = (0..self.dim).map(|_| rng.gaussian() as f32).collect();
                // orthogonalise w against u
                let uw = crate::core::matrix::dot_f64(&u, &w);
                for i in 0..self.dim {
                    w[i] -= uw as f32 * u[i];
                }
                crate::core::matrix::normalize(&mut w);
                let s = (1.0 - cos_t * cos_t).max(0.0).sqrt();
                let v: Vec<f32> = (0..self.dim)
                    .map(|i| (cos_t as f32) * u[i] + (s as f32) * w[i])
                    .collect();
                for p in planes.iter() {
                    let su = p.project(&u) >= 0.0;
                    let sv = p.project(&v) >= 0.0;
                    agree += (su == sv) as u64;
                    total += 1;
                }
            }
            curve[b] = agree as f64 / total.max(1) as f64;
        }
        // isotonic (pool adjacent violators) to enforce monotonicity in cos
        let mut level: Vec<f64> = Vec::new();
        let mut weight: Vec<f64> = Vec::new();
        for &c in &curve {
            level.push(c);
            weight.push(1.0);
            while level.len() > 1 && level[level.len() - 2] > level[level.len() - 1] {
                let (l1, w1) = (level.pop().unwrap(), weight.pop().unwrap());
                let (l0, w0) = (level.pop().unwrap(), weight.pop().unwrap());
                level.push((l0 * w0 + l1 * w1) / (w0 + w1));
                weight.push(w0 + w1);
            }
        }
        let mut bins_out = Vec::with_capacity(bins);
        for (lv, wt) in level.iter().zip(&weight) {
            for _ in 0..(*wt as usize) {
                bins_out.push(*lv);
            }
        }
        bins_out.resize(bins, *bins_out.last().unwrap_or(&0.5));
        CalibCurve { bins: bins_out }
    }

    /// The calibrated collision curve (diagnostics / tests).
    pub fn calibration(&self) -> &CalibCurve {
        &self.calib
    }

    /// Paper-default family: density 1/30.
    pub fn paper_default(dim: usize, k: usize, l: usize, seed: u64) -> Self {
        Self::new(dim, k, l, 1.0 / 30.0, seed)
    }

    /// Mean nonzeros per row (diagnostic).
    pub fn mean_nnz(&self) -> f64 {
        self.rows.iter().map(|r| r.nnz()).sum::<usize>() as f64 / self.rows.len() as f64
    }

    /// Configured density.
    pub fn density(&self) -> f64 {
        self.density
    }

    /// Per-plane canonical interleaved `(dim << 1 | sign)` entry lists —
    /// the snapshot payload (L·K rows, ascending dimension order each).
    pub(crate) fn row_entries(&self) -> Vec<&[u32]> {
        self.rows.iter().map(|r| r.entries.as_slice()).collect()
    }

    /// The calibrated collision bins (snapshot payload).
    pub(crate) fn calib_bins(&self) -> &[f64] {
        self.calib.bins()
    }

    /// Rebuild a family from snapshot parts: the CSC postings are
    /// recomputed with the same transpose as [`Self::new`] and the
    /// calibration curve is restored bit-exact, so codes *and* the
    /// Algorithm-1 probabilities of the restored family are identical to
    /// the saved one — without re-running the ~1M-add calibration.
    pub(crate) fn from_parts(
        dim: usize,
        k: usize,
        l: usize,
        density: f64,
        entries: Vec<Vec<u32>>,
        calib_bins: Vec<f64>,
    ) -> Result<Self> {
        if k == 0 || k > 32 || l == 0 || dim == 0 || entries.len() != l * k {
            return Err(Error::Store(format!(
                "sparse hasher parts inconsistent: dim {dim} k {k} l {l} with {} rows",
                entries.len()
            )));
        }
        if !(density > 0.0 && density <= 1.0) {
            return Err(Error::Store(format!("sparse hasher density {density} out of (0,1]")));
        }
        let mut rows = Vec::with_capacity(entries.len());
        for (i, e) in entries.into_iter().enumerate() {
            if e.is_empty() {
                return Err(Error::Store(format!("sparse plane row {i} has no entries")));
            }
            if e.iter().any(|&v| (v >> 1) as usize >= dim) {
                return Err(Error::Store(format!(
                    "sparse plane row {i} references a dimension >= {dim}"
                )));
            }
            rows.push(SparseRow { entries: e });
        }
        let (post_off, post) = Self::transpose(dim, &rows);
        Ok(SparseSrp {
            dim,
            k,
            l,
            density,
            rows,
            post_off,
            post,
            calib: CalibCurve::from_bins(calib_bins)?,
            counters: Arc::default(),
        })
    }
}

impl SrpHasher for SparseSrp {
    fn dim(&self) -> usize {
        self.dim
    }
    fn k(&self) -> usize {
        self.k
    }
    fn l(&self) -> usize {
        self.l
    }

    #[inline]
    fn code(&self, table: usize, x: &[f32]) -> u32 {
        debug_assert_eq!(x.len(), self.dim);
        self.counters.code.fetch_add(1, Ordering::Relaxed);
        let base = table * self.k;
        let mut c = 0u32;
        for b in 0..self.k {
            let s = self.rows[base + b].project(x);
            c = (c << 1) | (s >= 0.0) as u32;
        }
        c
    }

    fn mults_per_code(&self) -> f64 {
        // ±1 coefficients: additions only; we report the paper's accounting
        // of "multiplication-equivalent" work = expected nnz touched.
        self.k as f64 * self.dim as f64 * self.density
    }

    /// Fused one-pass sweep over the CSC postings: one sequential traversal
    /// of the query accumulating all `L·K` sparse projections, then one
    /// bit-pack pass — the §2.2 "d/30 multiplications for all hashes" cost
    /// model with cache-linear access. Per plane row the terms arrive in
    /// the same ascending-dimension order as [`SparseRow::project`] (zero
    /// terms included), so codes are bitwise-identical to `code()`.
    fn codes_all(&self, x: &[f32], out: &mut Vec<u32>) {
        debug_assert_eq!(x.len(), self.dim);
        self.counters.fused.fetch_add(1, Ordering::Relaxed);
        let lk = self.l * self.k;
        let mut acc = vec![0.0f64; lk];
        for i in 0..self.dim {
            let xi = x[i] as f64;
            let lo = self.post_off[i] as usize;
            let hi = self.post_off[i + 1] as usize;
            for &e in &self.post[lo..hi] {
                let a = &mut acc[(e >> 1) as usize];
                if e & 1 == 0 {
                    *a += xi;
                } else {
                    *a -= xi;
                }
            }
        }
        out.clear();
        for t in 0..self.l {
            let mut c = 0u32;
            for b in 0..self.k {
                c = (c << 1) | (acc[t * self.k + b] >= 0.0) as u32;
            }
            out.push(c);
        }
    }

    fn hash_stats(&self) -> HashStats {
        self.counters.snapshot()
    }

    fn collision_prob(&self, x: &[f32], q: &[f32]) -> f64 {
        // calibrated law of THIS family (see CalibCurve): O(d) cosine +
        // O(1) lookup
        self.calib.eval(crate::core::matrix::cosine(x, q))
    }

    fn collision_prob_normed(&self, x: &[f32], q: &[f32], nx: f64, nq: f64) -> f64 {
        if nx == 0.0 || nq == 0.0 {
            return 0.5;
        }
        // same shared cosine helper as the angular default; only the law
        // differs (calibrated curve instead of 1 − θ/π)
        self.calib.eval(normed_cosine(dot_fast(x, q) as f64, nx, nq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::angular_similarity;

    fn random_unit(dim: usize, rng: &mut Pcg64) -> Vec<f32> {
        let mut v: Vec<f32> = (0..dim).map(|_| rng.gaussian() as f32).collect();
        crate::core::matrix::normalize(&mut v);
        v
    }

    #[test]
    fn dense_code_is_deterministic_and_k_bits() {
        let h = DenseSrp::new(16, 7, 3, 42);
        let mut rng = Pcg64::seeded(1);
        let x = random_unit(16, &mut rng);
        for t in 0..3 {
            let c1 = h.code(t, &x);
            let c2 = h.code(t, &x);
            assert_eq!(c1, c2);
            assert!(c1 < (1 << 7));
        }
    }

    #[test]
    fn identical_vectors_always_collide() {
        let h = SparseSrp::new(32, 5, 10, 0.3, 7);
        let mut rng = Pcg64::seeded(2);
        let x = random_unit(32, &mut rng);
        for t in 0..10 {
            assert_eq!(h.code(t, &x), h.code(t, &x.clone()));
        }
    }

    #[test]
    fn opposite_vectors_never_collide_dense() {
        let h = DenseSrp::new(16, 5, 8, 3);
        let mut rng = Pcg64::seeded(3);
        let x = random_unit(16, &mut rng);
        let negx: Vec<f32> = x.iter().map(|v| -v).collect();
        for t in 0..8 {
            // every bit flips under negation (unless a projection is exactly 0,
            // which has measure zero) — codes are bitwise complements
            let cx = h.code(t, &x);
            let cn = h.code(t, &negx);
            assert_eq!(cx ^ cn, (1 << 5) - 1);
        }
    }

    /// Empirical per-bit collision rate matches 1 − θ/π for dense SRP.
    #[test]
    fn dense_collision_rate_matches_formula() {
        let dim = 24;
        let (k, l) = (1, 2000); // 2000 independent single-bit tables
        let h = DenseSrp::new(dim, k, l, 11);
        let mut rng = Pcg64::seeded(4);
        for _ in 0..4 {
            let x = random_unit(dim, &mut rng);
            let mut y = random_unit(dim, &mut rng);
            // Blend to get varied similarity levels.
            for i in 0..dim {
                y[i] = 0.7 * x[i] + 0.3 * y[i];
            }
            crate::core::matrix::normalize(&mut y);
            let expect = angular_similarity(&x, &y);
            let hits = (0..l).filter(|&t| h.code(t, &x) == h.code(t, &y)).count();
            let rate = hits as f64 / l as f64;
            assert!(
                (rate - expect).abs() < 0.05,
                "collision rate {rate} vs formula {expect}"
            );
        }
    }

    /// Sparse SRP approximates the same collision law (the ±1 variant of
    /// SimHash, [27] in the paper).
    #[test]
    fn sparse_collision_rate_tracks_formula() {
        let dim = 120;
        let (k, l) = (1, 3000);
        let h = SparseSrp::new(dim, k, l, 0.25, 13);
        let mut rng = Pcg64::seeded(6);
        let x = random_unit(dim, &mut rng);
        let mut y: Vec<f32> = x.clone();
        for v in y.iter_mut().take(40) {
            *v += rng.gaussian() as f32 * 0.3;
        }
        crate::core::matrix::normalize(&mut y);
        let expect = angular_similarity(&x, &y);
        let hits = (0..l).filter(|&t| h.code(t, &x) == h.code(t, &y)).count();
        let rate = hits as f64 / l as f64;
        assert!(
            (rate - expect).abs() < 0.08,
            "sparse collision rate {rate} vs formula {expect}"
        );
    }

    #[test]
    fn sparse_cost_model_below_dense() {
        let d = 90;
        let dense = DenseSrp::new(d, 5, 4, 1);
        let sparse = SparseSrp::paper_default(d, 5, 4, 1);
        assert!(sparse.mults_per_code() < dense.mults_per_code() / 10.0);
        // §2.2: all K hashes ≈ K·d/30 = d/6 "multiplications"
        assert!((sparse.mults_per_code() - 5.0 * 90.0 / 30.0).abs() < 1e-9);
        assert!(sparse.mean_nnz() >= 1.0);
    }

    #[test]
    #[should_panic]
    fn k_too_wide_panics() {
        let _ = DenseSrp::new(4, 33, 1, 0);
    }

    /// Fused `codes_all` is bitwise-identical to the per-table `code()`
    /// path for the dense family, across random dims/k/l and queries
    /// (including zero entries and non-unit vectors).
    #[test]
    fn prop_fused_codes_match_per_table_dense() {
        crate::testkit::prop(40, |rng| {
            let d = crate::testkit::gen::size(rng, 1, 40);
            let k = crate::testkit::gen::size(rng, 1, 8);
            let l = crate::testkit::gen::size(rng, 1, 12);
            let h = DenseSrp::new(d, k, l, rng.next_u64());
            let x: Vec<f32> = (0..d)
                .map(|_| if rng.bernoulli(0.2) { 0.0 } else { (rng.gaussian() * 3.0) as f32 })
                .collect();
            let mut fused = Vec::new();
            h.codes_all(&x, &mut fused);
            let per_table: Vec<u32> = (0..l).map(|t| h.code(t, &x)).collect();
            assert_eq!(fused, per_table, "dense fused codes diverged (d={d} k={k} l={l})");
        });
    }

    /// Same bitwise identity for the sparse family — the canonical
    /// interleaved entry order makes the CSC sweep replay `project`'s
    /// float ops exactly.
    #[test]
    fn prop_fused_codes_match_per_table_sparse() {
        crate::testkit::prop(40, |rng| {
            let d = crate::testkit::gen::size(rng, 1, 60);
            let k = crate::testkit::gen::size(rng, 1, 6);
            let l = crate::testkit::gen::size(rng, 1, 10);
            let density = 0.05 + rng.next_f64() * 0.5;
            let h = SparseSrp::new(d, k, l, density, rng.next_u64());
            let x: Vec<f32> = (0..d)
                .map(|_| if rng.bernoulli(0.2) { 0.0 } else { (rng.gaussian() * 2.0) as f32 })
                .collect();
            let mut fused = Vec::new();
            h.codes_all(&x, &mut fused);
            let per_table: Vec<u32> = (0..l).map(|t| h.code(t, &x)).collect();
            assert_eq!(fused, per_table, "sparse fused codes diverged (d={d} k={k} l={l})");
        });
    }

    /// Hash-invocation counters: `code()` and fused `codes_all` count
    /// separately, and clones of a family report into the same counters —
    /// the property the sharded hash-once assertion builds on.
    #[test]
    fn hash_counters_shared_across_clones() {
        let h = DenseSrp::new(8, 3, 5, 77);
        let clone = h.clone();
        let mut rng = Pcg64::seeded(7);
        let x = random_unit(8, &mut rng);
        assert_eq!(h.hash_stats(), HashStats::default());
        let _ = h.code(0, &x);
        let _ = clone.code(1, &x);
        let mut out = Vec::new();
        clone.codes_all(&x, &mut out);
        let s = h.hash_stats();
        assert_eq!(s.code_calls, 2, "one code() per call, shared across clones");
        assert_eq!(s.fused_calls, 1, "fused sweep counts once, not per table");
        assert_eq!(clone.hash_stats(), s);
        // the default (unfused) codes_all of the quadratic family falls
        // back to per-table code() calls and counts accordingly
        let q = crate::lsh::QuadraticSrp::new(6, 2, 4, 0.3, 5);
        let xq: Vec<f32> = random_unit(6, &mut rng);
        let mut cq = Vec::new();
        q.codes_all(&xq, &mut cq);
        assert_eq!(q.hash_stats(), HashStats { code_calls: 4, fused_calls: 0 });
    }

    /// The counters are exact under the multi-threaded draw path: clones
    /// hashing concurrently on many threads lose no updates (atomics, not
    /// a data race) — the invariant the async draw engine's shared-query
    /// assertions and the bench counters rely on.
    #[test]
    fn hash_counters_exact_under_parallel_hashing() {
        let h = DenseSrp::new(12, 3, 6, 5);
        let mut rng = Pcg64::seeded(9);
        let x = random_unit(12, &mut rng);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let hc = h.clone();
                let xr = &x;
                scope.spawn(move || {
                    let mut out = Vec::new();
                    for t in 0..25 {
                        let _ = hc.code(t % 6, xr);
                        hc.codes_all(xr, &mut out);
                    }
                });
            }
        });
        let s = h.hash_stats();
        assert_eq!(s.code_calls, 8 * 25, "no lost code() updates under parallel hashing");
        assert_eq!(s.fused_calls, 8 * 25, "no lost fused updates under parallel hashing");
    }
}
