//! Algorithm 1 — the LSH sampler — plus the Appendix B.2 minibatch variant
//! and the §2.2.1 near-neighbor-query cost comparator.
//!
//! The sampler probes uniformly-random tables until it finds a non-empty
//! bucket for the query, picks a uniform member of that bucket, and returns
//! the member together with its *exact* sampling probability
//! `p = cp^K (1−cp^K)^{l−1} / |S_b|` — the quantity LGD inverts for
//! unbiasedness.

use crate::core::matrix::Matrix;
use crate::core::rng::{Pcg64, Rng};
use crate::lsh::collision::sampling_probability;
use crate::lsh::srp::SrpHasher;
use crate::lsh::tables::BucketRead;

/// One sample drawn by Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Draw {
    /// Index of the sampled point in the hashed dataset.
    pub index: usize,
    /// Exact probability with which this point was returned.
    pub prob: f64,
    /// Number of tables probed before a non-empty bucket was found (`l`).
    pub probes: usize,
    /// Size of the accepted bucket (`|S_b|`).
    pub bucket_size: usize,
}

/// Cost counters for one query — feeds the §2.2 running-time table.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SampleCost {
    /// Meta-hash codes computed (lazily per probed table, or L at once on a
    /// fused refresh).
    pub codes: usize,
    /// Multiplication-equivalent work of those codes.
    pub mults: f64,
    /// Random numbers drawn.
    pub randoms: usize,
    /// Bucket probes performed (one table lookup each) — the sealed-vs-Vec
    /// benchmarks report this alongside ns/draw to show the layouts do
    /// identical logical work.
    pub probes: usize,
}

impl SampleCost {
    /// Fold another counter set into this one.
    pub fn absorb(&mut self, other: &SampleCost) {
        self.codes += other.codes;
        self.mults += other.mults;
        self.randoms += other.randoms;
        self.probes += other.probes;
    }
}

/// Outcome of a sampling attempt.
#[derive(Debug, Clone)]
pub enum Sampled {
    /// Normal path: a point with its probability.
    Hit(Draw),
    /// All probed buckets were empty (pathological K too large / tiny data);
    /// the caller should fall back to a uniform draw. Counted by the
    /// coordinator's metrics — with the paper's K=5 this is essentially
    /// never hit.
    Exhausted { probes: usize },
}

/// Cached query state for amortising hash computations across draws.
///
/// The query `[θ_t, −1]` drifts slowly between SGD steps, so its K-bit
/// table codes can be reused for several draws ("stale query"). The
/// sampling distribution is then the one *defined by the cached query*,
/// whose probabilities we compute exactly — importance weighting keeps the
/// estimator unbiased for any fixed proposal, so staleness costs nothing
/// in expectation, only (slightly) in adaptivity. This is the same
/// amortisation Appendix E applies to BERT representations, and it is what
/// brings the per-iteration hash cost down to the paper's ~1.5× SGD.
#[derive(Debug, Clone, Default)]
pub struct QueryCache {
    /// The query the codes were computed for.
    pub query: Vec<f32>,
    /// Per-table codes of `query` (lazy via [`QueryCache::refresh`], eager
    /// via [`QueryCache::refresh_fused`]).
    codes: Vec<Option<u32>>,
    /// Draws served since the last refresh.
    pub age: usize,
    /// ‖query‖ (precomputed at refresh for the cp hot path).
    pub norm: f64,
    /// Reusable buffer for the fused refresh.
    scratch: Vec<u32>,
}

impl QueryCache {
    /// Replace the cached query (clears the codes; they fill lazily, one
    /// `code()` per first-probed table).
    pub fn refresh(&mut self, query: &[f32], l: usize) {
        self.query.clear();
        self.query.extend_from_slice(query);
        self.codes.clear();
        self.codes.resize(l, None);
        self.age = 0;
        self.norm = crate::core::matrix::norm2(query);
    }

    /// Replace the cached query and compute **all** L codes eagerly in one
    /// fused [`SrpHasher::codes_all`] sweep — same multiplications as the
    /// lazy fill eventually pays, one sequential pass, exactly one hash
    /// invocation per refresh no matter how many shards consult the cache.
    /// The full sweep's cost is charged to `cost` here.
    pub fn refresh_fused<H: SrpHasher>(
        &mut self,
        query: &[f32],
        hasher: &H,
        cost: &mut SampleCost,
    ) {
        self.query.clear();
        self.query.extend_from_slice(query);
        let mut codes = std::mem::take(&mut self.scratch);
        hasher.codes_all(query, &mut codes);
        self.codes.clear();
        self.codes.extend(codes.iter().map(|&c| Some(c)));
        self.scratch = codes;
        self.age = 0;
        self.norm = crate::core::matrix::norm2(query);
        cost.codes += hasher.l();
        cost.mults += hasher.mults_all();
    }

    /// True if `refresh` has never been called.
    pub fn is_empty(&self) -> bool {
        self.query.is_empty()
    }

    /// Snapshot view: `(query, per-table codes, age, norm)`. Persisting the
    /// cache keeps a restored estimator mid-refresh-window, so its single-
    /// draw stream continues exactly where the saved one stopped (refresh
    /// *timing* is part of the stream when θ moves between draws).
    pub(crate) fn snapshot_parts(&self) -> (&[f32], &[Option<u32>], usize, f64) {
        (&self.query, &self.codes, self.age, self.norm)
    }

    /// Rebuild from [`Self::snapshot_parts`].
    pub(crate) fn from_parts(
        query: Vec<f32>,
        codes: Vec<Option<u32>>,
        age: usize,
        norm: f64,
    ) -> QueryCache {
        QueryCache { query, codes, age, norm, scratch: Vec::new() }
    }
}

/// The LSH sampler: borrows a bucket store (Vec-backed or sealed — any
/// [`BucketRead`]) and the hashed vectors (needed to compute exact
/// collision probabilities at draw time).
pub struct LshSampler<'a, T: BucketRead> {
    tables: &'a T,
    /// Hashed vectors, row i = vector inserted with id i.
    hashed: &'a Matrix,
    /// Precomputed ‖row_i‖ (cp hot path).
    norms: std::borrow::Cow<'a, [f64]>,
    /// Probe cap: Algorithm 1 as written loops forever; we cap at
    /// `max_probes` (default 4·L) and report `Exhausted`.
    max_probes: usize,
}

impl<'a, T: BucketRead> LshSampler<'a, T> {
    /// Wrap tables + the matrix of the vectors that were inserted into them.
    pub fn new(tables: &'a T, hashed: &'a Matrix) -> Self {
        Self::with_norms(tables, hashed, std::borrow::Cow::Owned(hashed.row_norms()))
    }

    /// Construct with precomputed row norms (hot path: callers that build a
    /// sampler per draw precompute norms once and lend them here).
    pub fn with_norms(
        tables: &'a T,
        hashed: &'a Matrix,
        norms: std::borrow::Cow<'a, [f64]>,
    ) -> Self {
        debug_assert_eq!(norms.len(), hashed.rows());
        let max_probes = 4 * tables.hasher().l();
        LshSampler { tables, hashed, norms, max_probes }
    }

    /// Override the probe cap.
    pub fn with_max_probes(mut self, cap: usize) -> Self {
        self.max_probes = cap.max(1);
        self
    }

    /// Algorithm 1. Returns the draw and accumulates cost counters.
    pub fn sample(&self, query: &[f32], rng: &mut Pcg64, cost: &mut SampleCost) -> Sampled {
        let l_tables = self.tables.hasher().l();
        let k = self.tables.hasher().k();
        let mut probes = 0usize;
        loop {
            probes += 1;
            if probes > self.max_probes {
                return Sampled::Exhausted { probes: probes - 1 };
            }
            // ti = random(1, L)
            let ti = rng.index(l_tables);
            cost.randoms += 1;
            cost.probes += 1;
            let code = self.tables.hasher().code(ti, query);
            let bucket = self.tables.view(ti, code);
            cost.codes += 1;
            cost.mults += self.tables.hasher().mults_per_code();
            if bucket.is_empty() {
                continue;
            }
            // x = random element of the bucket
            let pick = rng.index(bucket.len());
            cost.randoms += 1;
            let index = bucket.get(pick) as usize;
            let cp = self.tables.hasher().collision_prob(self.hashed.row(index), query);
            let prob = sampling_probability(cp, k, probes, bucket.len());
            return Sampled::Hit(Draw { index, prob, probes, bucket_size: bucket.len() });
        }
    }

    /// Algorithm 1 with the query's per-table codes precomputed — the
    /// shared-query-code contract: the estimator hashes the query once
    /// (one fused `codes_all`) and passes the codes to every shard's
    /// sampler. Code computation consumes no randomness, so this draws the
    /// *identical* sequence to [`Self::sample`]/[`Self::sample_cached`]
    /// under the same RNG state; hashing cost is accounted by the caller
    /// at the fused pass, not here.
    pub fn sample_coded(
        &self,
        codes: &[u32],
        query: &[f32],
        rng: &mut Pcg64,
        cost: &mut SampleCost,
    ) -> Sampled {
        let l_tables = self.tables.hasher().l();
        debug_assert_eq!(codes.len(), l_tables);
        let k = self.tables.hasher().k();
        let mut probes = 0usize;
        loop {
            probes += 1;
            if probes > self.max_probes {
                return Sampled::Exhausted { probes: probes - 1 };
            }
            let ti = rng.index(l_tables);
            cost.randoms += 1;
            cost.probes += 1;
            let bucket = self.tables.view(ti, codes[ti]);
            if bucket.is_empty() {
                continue;
            }
            let pick = rng.index(bucket.len());
            cost.randoms += 1;
            let index = bucket.get(pick) as usize;
            let cp = self.tables.hasher().collision_prob(self.hashed.row(index), query);
            let prob = sampling_probability(cp, k, probes, bucket.len());
            return Sampled::Hit(Draw { index, prob, probes, bucket_size: bucket.len() });
        }
    }

    /// Algorithm 1 against a cached query: identical distribution to
    /// [`Self::sample`] with `cache.query`, but table codes are computed at
    /// most once per (cache refresh, table) — the §Perf amortisation.
    pub fn sample_cached(
        &self,
        cache: &mut QueryCache,
        rng: &mut Pcg64,
        cost: &mut SampleCost,
    ) -> Sampled {
        debug_assert!(!cache.is_empty(), "QueryCache::refresh before sampling");
        let l_tables = self.tables.hasher().l();
        let k = self.tables.hasher().k();
        let mut probes = 0usize;
        cache.age += 1;
        loop {
            probes += 1;
            if probes > self.max_probes {
                return Sampled::Exhausted { probes: probes - 1 };
            }
            let ti = rng.index(l_tables);
            cost.randoms += 1;
            cost.probes += 1;
            let code = match cache.codes[ti] {
                Some(c) => c,
                None => {
                    let c = self.tables.hasher().code(ti, &cache.query);
                    cost.codes += 1;
                    cost.mults += self.tables.hasher().mults_per_code();
                    cache.codes[ti] = Some(c);
                    c
                }
            };
            let bucket = self.tables.view(ti, code);
            if bucket.is_empty() {
                continue;
            }
            let pick = rng.index(bucket.len());
            cost.randoms += 1;
            let index = bucket.get(pick) as usize;
            let cp = self.tables.hasher().collision_prob_normed(
                self.hashed.row(index),
                &cache.query,
                self.norms[index],
                cache.norm,
            );
            let prob = sampling_probability(cp, k, probes, bucket.len());
            return Sampled::Hit(Draw { index, prob, probes, bucket_size: bucket.len() });
        }
    }

    /// Appendix B.2 minibatch sampling: draw `m` points. If the first
    /// non-empty bucket holds fewer than `m`, keep probing further tables
    /// and drawing from their buckets. Draws within a bucket are *with
    /// replacement* so each returned `Draw` carries an exact per-draw
    /// probability (keeps Thm 1 unbiasedness for the mean-of-draws
    /// estimator).
    pub fn sample_batch(
        &self,
        query: &[f32],
        m: usize,
        rng: &mut Pcg64,
        cost: &mut SampleCost,
        out: &mut Vec<Draw>,
    ) {
        out.clear();
        let l_tables = self.tables.hasher().l();
        let k = self.tables.hasher().k();
        let mut probes = 0usize;
        while out.len() < m && probes < self.max_probes {
            probes += 1;
            let ti = rng.index(l_tables);
            cost.randoms += 1;
            cost.probes += 1;
            let code = self.tables.hasher().code(ti, query);
            let bucket = self.tables.view(ti, code);
            cost.codes += 1;
            cost.mults += self.tables.hasher().mults_per_code();
            if bucket.is_empty() {
                continue;
            }
            let want = m - out.len();
            // B.2: draws are *with replacement*, so even a bucket smaller
            // than `want` can satisfy the whole remaining request — capping
            // at the bucket size would silently burn probes and trigger
            // spurious uniform fallbacks upstream.
            let take = want;
            for _ in 0..take {
                let pick = rng.index(bucket.len());
                cost.randoms += 1;
                let index = bucket.get(pick) as usize;
                let cp = self.tables.hasher().collision_prob(self.hashed.row(index), query);
                let prob = sampling_probability(cp, k, probes, bucket.len());
                out.push(Draw { index, prob, probes, bucket_size: bucket.len() });
            }
        }
    }

    /// [`Self::sample_batch`] with precomputed per-table codes — the batch
    /// side of the shared-query-code contract. The caller hashes the query
    /// once (fused) per batch; every shard's quota is then filled without
    /// re-hashing, and probe-heavy batches stop paying one code per probe.
    /// Identical RNG stream and draw sequence to `sample_batch`.
    pub fn sample_batch_coded(
        &self,
        codes: &[u32],
        query: &[f32],
        m: usize,
        rng: &mut Pcg64,
        cost: &mut SampleCost,
        out: &mut Vec<Draw>,
    ) {
        out.clear();
        let l_tables = self.tables.hasher().l();
        debug_assert_eq!(codes.len(), l_tables);
        let k = self.tables.hasher().k();
        let mut probes = 0usize;
        while out.len() < m && probes < self.max_probes {
            probes += 1;
            let ti = rng.index(l_tables);
            cost.randoms += 1;
            cost.probes += 1;
            let bucket = self.tables.view(ti, codes[ti]);
            if bucket.is_empty() {
                continue;
            }
            let want = m - out.len();
            for _ in 0..want {
                let pick = rng.index(bucket.len());
                cost.randoms += 1;
                let index = bucket.get(pick) as usize;
                let cp = self.tables.hasher().collision_prob(self.hashed.row(index), query);
                let prob = sampling_probability(cp, k, probes, bucket.len());
                out.push(Draw { index, prob, probes, bucket_size: bucket.len() });
            }
        }
    }

    /// §2.2.1 comparator: a full near-neighbor query — candidate generation
    /// over all L buckets ([`BucketRead::candidate_union`]) plus distance
    /// filtering. Returns the best candidate and the number of candidate
    /// distance evaluations performed (the cost LGD avoids). This is
    /// intentionally the *expensive* path.
    pub fn nn_query(&self, query: &[f32]) -> (Option<usize>, usize) {
        let cands = self.tables.candidate_union(query);
        let evals = cands.len();
        let mut best: Option<(usize, f64)> = None;
        for id in cands {
            let sim = crate::core::matrix::cosine(self.hashed.row(id as usize), query);
            match best {
                Some((_, s)) if s >= sim => {}
                _ => best = Some((id as usize, sim)),
            }
        }
        (best.map(|(i, _)| i), evals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::normalize;
    use crate::lsh::srp::DenseSrp;
    use crate::lsh::tables::LshTables;

    /// Build a small hashed dataset of unit vectors.
    fn setup(n: usize, d: usize, k: usize, l: usize, seed: u64) -> (LshTables<DenseSrp>, Matrix) {
        let mut rng = Pcg64::seeded(seed);
        let mut m = Matrix::zeros(0, 0);
        for _ in 0..n {
            let mut v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            normalize(&mut v);
            m.push_row(&v).unwrap();
        }
        let h = DenseSrp::new(d, k, l, seed ^ 0xABCD);
        let t = LshTables::build(h, (0..n).map(|i| m.row(i))).unwrap();
        (t, m)
    }

    #[test]
    fn sample_returns_valid_draw() {
        let (t, m) = setup(200, 16, 4, 20, 1);
        let s = LshSampler::new(&t, &m);
        let mut rng = Pcg64::seeded(2);
        let mut q: Vec<f32> = (0..16).map(|_| rng.gaussian() as f32).collect();
        normalize(&mut q);
        let mut cost = SampleCost::default();
        for _ in 0..200 {
            match s.sample(&q, &mut rng, &mut cost) {
                Sampled::Hit(d) => {
                    assert!(d.index < 200);
                    assert!(d.prob > 0.0 && d.prob <= 1.0);
                    assert!(d.probes >= 1);
                    assert!(d.bucket_size >= 1);
                }
                Sampled::Exhausted { .. } => panic!("should not exhaust with K=4"),
            }
        }
        assert!(cost.codes >= 200);
        assert!(cost.randoms >= 400);
    }

    /// Exact-distribution check of the sampler implementation. Conditional
    /// on a fixed table build, Algorithm 1 (probe uniformly random tables
    /// with replacement until non-empty, then uniform within bucket) draws
    /// point i with probability
    /// `p_true(i) = (1/#nonempty) Σ_{t nonempty} 1{i ∈ B_t(q)} / |B_t(q)|`.
    /// Empirical frequencies must match this enumeration. (Theorem 1's
    /// formula-based probability is an *ensemble* quantity; its role in the
    /// unbiased estimator is validated in `estimator::lgd` tests.)
    #[test]
    fn empirical_frequency_matches_exact_conditional_distribution() {
        let (t, m) = setup(60, 8, 3, 16, 3);
        let s = LshSampler::new(&t, &m);
        let mut rng = Pcg64::seeded(4);
        let mut q: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
        normalize(&mut q);

        // Enumerate the exact conditional distribution.
        let mut p_true = vec![0.0f64; 60];
        let mut nonempty = 0usize;
        for ti in 0..16 {
            let b = t.query_bucket(ti, &q);
            if b.is_empty() {
                continue;
            }
            nonempty += 1;
            for &id in b {
                p_true[id as usize] += 1.0 / b.len() as f64;
            }
        }
        assert!(nonempty > 0);
        for p in p_true.iter_mut() {
            *p /= nonempty as f64;
        }

        let trials = 120_000;
        let mut counts = vec![0usize; 60];
        let mut cost = SampleCost::default();
        for _ in 0..trials {
            match s.sample(&q, &mut rng, &mut cost) {
                Sampled::Hit(d) => counts[d.index] += 1,
                Sampled::Exhausted { .. } => panic!("tables are non-empty"),
            }
        }
        for i in 0..60 {
            let freq = counts[i] as f64 / trials as f64;
            let expect = p_true[i];
            if expect == 0.0 {
                assert_eq!(counts[i], 0, "point {i} drawn despite p_true = 0");
            } else if expect > 0.005 {
                let rel = (freq - expect).abs() / expect;
                assert!(rel < 0.15, "point {i}: freq {freq:.5} vs exact {expect:.5}");
            }
        }
    }

    /// The headline *adaptivity* property: points similar to the query are
    /// drawn more often than dissimilar ones.
    #[test]
    fn sampling_is_monotone_in_similarity() {
        let (t, m) = setup(300, 12, 5, 30, 7);
        let s = LshSampler::new(&t, &m);
        let mut rng = Pcg64::seeded(8);
        // query = a point of the dataset, so similarity varies widely
        let q: Vec<f32> = m.row(0).to_vec();
        let mut counts = vec![0usize; 300];
        let mut cost = SampleCost::default();
        for _ in 0..40_000 {
            if let Sampled::Hit(d) = s.sample(&q, &mut rng, &mut cost) {
                counts[d.index] += 1;
            }
        }
        let sims: Vec<f64> = (0..300).map(|i| crate::core::matrix::cosine(m.row(i), &q)).collect();
        let freqs: Vec<f64> = counts.iter().map(|&c| c as f64).collect();
        let rho = crate::core::stats::spearman(&sims, &freqs);
        assert!(rho > 0.4, "sampling frequency not monotone in similarity: rho={rho}");
    }

    #[test]
    fn batch_sampling_returns_m_draws() {
        let (t, m) = setup(100, 10, 3, 10, 9);
        let s = LshSampler::new(&t, &m);
        let mut rng = Pcg64::seeded(10);
        let q: Vec<f32> = m.row(5).to_vec();
        let mut cost = SampleCost::default();
        let mut out = Vec::new();
        s.sample_batch(&q, 32, &mut rng, &mut cost, &mut out);
        assert_eq!(out.len(), 32);
        for d in &out {
            assert!(d.prob > 0.0 && d.prob <= 1.0);
            assert!(d.index < 100);
        }
    }

    /// Regression: with-replacement semantics mean one non-empty bucket —
    /// however small — satisfies an arbitrarily large batch. Ten identical
    /// points share one bucket; with a probe budget of 1 the old
    /// `min(bucket.len())` cap could only return 10 of the 32 requested
    /// draws.
    #[test]
    fn small_bucket_satisfies_large_batch_with_replacement() {
        let mut m = Matrix::zeros(0, 0);
        let v = {
            let mut v = vec![1.0f32; 6];
            normalize(&mut v);
            v
        };
        for _ in 0..10 {
            m.push_row(&v).unwrap();
        }
        let h = DenseSrp::new(6, 3, 4, 5);
        let t = LshTables::build(h, (0..10).map(|i| m.row(i))).unwrap();
        let s = LshSampler::new(&t, &m).with_max_probes(1);
        let mut rng = Pcg64::seeded(6);
        let mut cost = SampleCost::default();
        let mut out = Vec::new();
        s.sample_batch(&v, 32, &mut rng, &mut cost, &mut out);
        assert_eq!(out.len(), 32, "one probe must fill the whole batch");
        for d in &out {
            assert!(d.index < 10);
            assert!(d.prob > 0.0 && d.prob <= 1.0);
            assert_eq!(d.bucket_size, 10);
        }
    }

    /// The coded entry points (precomputed fused codes) consume the same
    /// RNG stream and return the same draws as the hashing paths — over
    /// both the Vec layout and the sealed arena.
    #[test]
    fn coded_paths_match_uncoded_draw_for_draw() {
        let (t, m) = setup(150, 10, 3, 12, 17);
        let h = t.hasher().clone();
        let sealed = {
            let rebuilt = LshTables::build(h.clone(), (0..150).map(|i| m.row(i))).unwrap();
            rebuilt.seal()
        };
        let mut q: Vec<f32> = m.row(9).to_vec();
        q[0] += 0.05;
        let mut codes = Vec::new();
        h.codes_all(&q, &mut codes);

        let s_vec = LshSampler::new(&t, &m);
        let s_sealed = LshSampler::new(&sealed, &m);
        let (mut r1, mut r2, mut r3) = (Pcg64::seeded(5), Pcg64::seeded(5), Pcg64::seeded(5));
        let mut c = SampleCost::default();
        for i in 0..300 {
            let a = s_vec.sample(&q, &mut r1, &mut c);
            let b = s_vec.sample_coded(&codes, &q, &mut r2, &mut c);
            let d = s_sealed.sample_coded(&codes, &q, &mut r3, &mut c);
            match (a, b, d) {
                (Sampled::Hit(a), Sampled::Hit(b), Sampled::Hit(d)) => {
                    assert_eq!(a, b, "draw {i}: coded path diverged");
                    assert_eq!(a, d, "draw {i}: sealed coded path diverged");
                }
                _ => panic!("draw {i}: unexpected exhaustion"),
            }
        }
        let (mut r1, mut r2, mut r3) = (Pcg64::seeded(9), Pcg64::seeded(9), Pcg64::seeded(9));
        let (mut o1, mut o2, mut o3) = (Vec::new(), Vec::new(), Vec::new());
        s_vec.sample_batch(&q, 64, &mut r1, &mut c, &mut o1);
        s_vec.sample_batch_coded(&codes, &q, 64, &mut r2, &mut c, &mut o2);
        s_sealed.sample_batch_coded(&codes, &q, 64, &mut r3, &mut c, &mut o3);
        assert_eq!(o1, o2, "batch coded path diverged");
        assert_eq!(o1, o3, "sealed batch coded path diverged");
    }

    #[test]
    fn with_max_probes_floors_at_one() {
        let (t, m) = setup(20, 6, 3, 4, 13);
        let s = LshSampler::new(&t, &m).with_max_probes(0);
        let mut rng = Pcg64::seeded(14);
        let mut cost = SampleCost::default();
        // cap of 0 is clamped to 1 probe, not an infinite loop or panic
        let q: Vec<f32> = m.row(0).to_vec();
        match s.sample(&q, &mut rng, &mut cost) {
            Sampled::Hit(d) => assert_eq!(d.probes, 1),
            Sampled::Exhausted { probes } => assert_eq!(probes, 1),
        }
    }

    #[test]
    fn exhausted_on_empty_tables() {
        let h = DenseSrp::new(4, 3, 5, 0);
        let t: LshTables<DenseSrp> = LshTables::new(h);
        let m = Matrix::zeros(0, 0);
        let s = LshSampler::new(&t, &m).with_max_probes(8);
        let mut rng = Pcg64::seeded(1);
        let mut cost = SampleCost::default();
        match s.sample(&[1.0, 0.0, 0.0, 0.0], &mut rng, &mut cost) {
            Sampled::Exhausted { probes } => assert_eq!(probes, 8),
            _ => panic!("must exhaust on empty tables"),
        }
    }

    #[test]
    fn nn_query_touches_more_candidates_than_sampling() {
        let (t, m) = setup(500, 12, 4, 40, 11);
        let s = LshSampler::new(&t, &m);
        let q: Vec<f32> = m.row(42).to_vec();
        let (best, evals) = s.nn_query(&q);
        // The query point itself collides with itself in all 40 tables.
        assert_eq!(best, Some(42), "nn query should find the identical point");
        // §2.2.1: candidate generation is far more work than one probe.
        assert!(evals > 10, "nn candidate set suspiciously small: {evals}");
    }
}
