//! Locality-sensitive hashing substrate: SimHash families (dense, sparse,
//! implicit-quadratic), (K, L) tables, the Algorithm-1 sampler, and the
//! collision-probability formulas LGD's unbiased estimator depends on.

pub mod collision;
pub mod quadratic;
pub mod sampler;
pub mod srp;
pub mod tables;

pub use collision::{bucket_match_prob, quadratic_cp, sampling_probability, simhash_cp};
pub use quadratic::QuadraticSrp;
pub use sampler::{Draw, LshSampler, SampleCost, Sampled};
pub use srp::{DenseSrp, HashStats, SparseSrp, SrpHasher};
pub use tables::{BucketRead, BucketView, LshTables, SealedTables, TableStats, TableStore};

use crate::config::spec::{HasherKind, LshConfig};

/// One concrete hash family behind a kind tag — THE single
/// `HasherKind` → constructor dispatch in the codebase. The trainer's
/// boxed estimator builder, the monomorphized LGD training loop and the
/// snapshot loader all obtain their family here (previously the match was
/// written once per call site, flagged by the PR-4 review; warm-start would
/// have made a third copy).
///
/// `Clone` clones the wrapped family; every family's hash-invocation
/// counters live behind a shared `Arc`, so a clone reports into the same
/// cells — the handle pattern the zero-rebuild proofs rely on.
#[derive(Clone)]
pub enum AnyHasher {
    /// Dense N(0,1) SimHash.
    Dense(DenseSrp),
    /// Very sparse ±1 projections with a calibrated collision law.
    Sparse(SparseSrp),
    /// Implicit quadratic feature-map SRP.
    Quadratic(QuadraticSrp),
}

/// A generic computation over a concrete hash family. `AnyHasher::visit`
/// monomorphizes the visitor per family, so generic engines (the sharded
/// estimator, the draw engine, the snapshot restore path) never need their
/// own kind dispatch.
///
/// The bound deliberately includes `store::snapshot::SnapshotHasher` even
/// though that trait lives a layer up: trait impls cannot *strengthen* the
/// method bounds, so persistence-needing visitors (the trainer's autosave
/// path) can only exist if the capability is guaranteed here — and under
/// the production north star every servable family must be persistable
/// anyway. The cost is that a new family must ship its `SnapshotHasher`
/// impl before it can be dispatched at all, which is the intended
/// forcing function (an un-snapshottable index would silently re-pay the
/// §2.2 one-time cost on every restart).
pub trait HasherVisitor {
    /// Result of the computation.
    type Out;
    /// Run with the concrete family.
    fn visit<H>(self, hasher: H) -> Self::Out
    where
        H: crate::store::snapshot::SnapshotHasher + Clone + 'static;
}

impl AnyHasher {
    /// Construct the family an `[lsh]` config block describes, over hash
    /// space dimension `dim`.
    pub fn from_lsh_config(lsh: &LshConfig, dim: usize) -> AnyHasher {
        match lsh.hasher {
            HasherKind::Dense => AnyHasher::Dense(DenseSrp::new(dim, lsh.k, lsh.l, lsh.seed)),
            HasherKind::Sparse => {
                AnyHasher::Sparse(SparseSrp::new(dim, lsh.k, lsh.l, lsh.density, lsh.seed))
            }
            HasherKind::Quadratic => {
                AnyHasher::Quadratic(QuadraticSrp::new(dim, lsh.k, lsh.l, lsh.density, lsh.seed))
            }
        }
    }

    /// Which config kind this family is.
    pub fn kind(&self) -> HasherKind {
        match self {
            AnyHasher::Dense(_) => HasherKind::Dense,
            AnyHasher::Sparse(_) => HasherKind::Sparse,
            AnyHasher::Quadratic(_) => HasherKind::Quadratic,
        }
    }

    /// Shared hash-invocation counters of the wrapped family (clones report
    /// into the same cells — the zero-rebuild proof reads these).
    pub fn hash_stats(&self) -> HashStats {
        match self {
            AnyHasher::Dense(h) => h.hash_stats(),
            AnyHasher::Sparse(h) => h.hash_stats(),
            AnyHasher::Quadratic(h) => h.hash_stats(),
        }
    }

    /// Meta-hash width of the wrapped family.
    pub fn k(&self) -> usize {
        match self {
            AnyHasher::Dense(h) => h.k(),
            AnyHasher::Sparse(h) => h.k(),
            AnyHasher::Quadratic(h) => h.k(),
        }
    }

    /// Table count of the wrapped family.
    pub fn l(&self) -> usize {
        match self {
            AnyHasher::Dense(h) => h.l(),
            AnyHasher::Sparse(h) => h.l(),
            AnyHasher::Quadratic(h) => h.l(),
        }
    }

    /// Monomorphize `v` over the concrete family.
    pub fn visit<V: HasherVisitor>(self, v: V) -> V::Out {
        match self {
            AnyHasher::Dense(h) => v.visit(h),
            AnyHasher::Sparse(h) => v.visit(h),
            AnyHasher::Quadratic(h) => v.visit(h),
        }
    }
}
