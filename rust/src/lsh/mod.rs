//! Locality-sensitive hashing substrate: SimHash families (dense, sparse,
//! implicit-quadratic), (K, L) tables, the Algorithm-1 sampler, and the
//! collision-probability formulas LGD's unbiased estimator depends on.

pub mod collision;
pub mod quadratic;
pub mod sampler;
pub mod srp;
pub mod tables;

pub use collision::{bucket_match_prob, quadratic_cp, sampling_probability, simhash_cp};
pub use quadratic::QuadraticSrp;
pub use sampler::{Draw, LshSampler, SampleCost, Sampled};
pub use srp::{DenseSrp, HashStats, SparseSrp, SrpHasher};
pub use tables::{BucketRead, BucketView, LshTables, SealedTables, TableStats, TableStore};
