//! Dataset substrate: representation, synthetic workload generators matched
//! to the paper's Table 4, CSV I/O, §2.2 preprocessing, and sharding.

pub mod csv;
pub mod dataset;
pub mod preprocess;
pub mod seq;
pub mod shard;
pub mod synth;

pub use dataset::{Dataset, Task};
pub use preprocess::{preprocess, HashSpace, Preprocessed, PreprocessOptions};
pub use synth::{paper_specs, SynthSpec};
