//! §2.1/§2.2 preprocessing: row normalisation, the hash-space embeddings for
//! linear and logistic regression, optional centering, and query builders.
//!
//! Hash space vs. gradient space:
//!
//! * **linear regression** — store `v_i = [x_i, y_i]`, query
//!   `q_t = [θ_t, −1]`; then `⟨q_t, v_i⟩ = θ_t·x_i − y_i`, whose absolute
//!   value (times 2‖x_i‖) is the gradient norm (eq. 4).
//! * **logistic regression** — store `v_i = y_i·x_i`, query `q_t = −θ_t`;
//!   `⟨q_t, v_i⟩ = −y_iθ_t·x_i` is monotone in the gradient norm
//!   `1/(e^{y_iθ·x_i}+1)` (eq. 11).
//!
//! The gradient itself is always computed on the *original* (normalised)
//! features — the hash space only drives sampling.

use crate::core::error::Result;
use crate::core::matrix::{normalize, Matrix};
use crate::data::dataset::{Dataset, Task};

/// How raw examples are embedded into the hash space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HashSpace {
    /// `[x_i, y_i]` with query `[θ, −1]` (linear regression, eq. 4).
    LinRegAugmented,
    /// `y_i · x_i` with query `−θ` (logistic regression, eq. 11).
    LogRegSigned,
}

impl HashSpace {
    /// Default hash space for a task.
    pub fn for_task(task: Task) -> Self {
        match task {
            Task::Regression => HashSpace::LinRegAugmented,
            Task::Classification => HashSpace::LogRegSigned,
        }
    }

    /// Hash-space dimensionality given feature dimensionality `d`.
    pub fn dim(&self, d: usize) -> usize {
        match self {
            HashSpace::LinRegAugmented => d + 1,
            HashSpace::LogRegSigned => d,
        }
    }
}

/// A dataset prepared for LGD: normalised features plus the matrix of
/// hash-space vectors that went into the LSH tables.
#[derive(Debug, Clone)]
pub struct Preprocessed {
    /// The training dataset with unit-norm rows.
    pub data: Dataset,
    /// Hash-space vectors (one row per example) — what the tables index.
    pub hashed: Matrix,
    /// Hash-space used.
    pub space: HashSpace,
    /// Mean subtracted from stored vectors (empty when centering disabled).
    pub center: Vec<f32>,
    /// Original row norms before normalisation (diagnostics).
    pub norms: Vec<f64>,
}

impl Preprocessed {
    /// Build the query vector for parameter `theta` in this hash space.
    /// When centering was applied to the stored vectors, the same shift is
    /// applied to the query so cosine geometry stays consistent.
    pub fn query(&self, theta: &[f32], out: &mut Vec<f32>) {
        out.clear();
        match self.space {
            HashSpace::LinRegAugmented => {
                out.extend_from_slice(theta);
                out.push(-1.0);
            }
            HashSpace::LogRegSigned => {
                out.extend(theta.iter().map(|v| -v));
            }
        }
    }
}

/// Options for preprocessing.
#[derive(Debug, Clone)]
pub struct PreprocessOptions {
    /// Center stored hash vectors at their mean (§2.2 "we centered the
    /// data... to make the simhash query more efficient"). Off by default:
    /// centering perturbs the exact-probability accounting, so the default
    /// configuration keeps Thm 1 exact and centering is an ablation.
    pub center: bool,
}

impl Default for PreprocessOptions {
    fn default() -> Self {
        PreprocessOptions { center: false }
    }
}

/// Normalise features to unit norm and build hash-space vectors.
pub fn preprocess(mut ds: Dataset, opts: &PreprocessOptions) -> Result<Preprocessed> {
    let n = ds.len();
    let d = ds.dim();
    let space = HashSpace::for_task(ds.task);
    let mut norms = Vec::with_capacity(n);
    for i in 0..n {
        let norm = normalize(ds.x.row_mut(i));
        norms.push(norm);
    }
    let hd = space.dim(d);
    let mut hashed = Matrix::zeros(n, hd);
    for i in 0..n {
        let (xi, yi) = ds.example(i);
        let row = hashed.row_mut(i);
        match space {
            HashSpace::LinRegAugmented => {
                row[..d].copy_from_slice(xi);
                row[d] = yi;
            }
            HashSpace::LogRegSigned => {
                for j in 0..d {
                    row[j] = yi * xi[j];
                }
            }
        }
    }
    let mut center = Vec::new();
    if opts.center {
        center = vec![0.0f32; hd];
        for i in 0..n {
            for (c, &v) in center.iter_mut().zip(hashed.row(i)) {
                *c += v;
            }
        }
        for c in center.iter_mut() {
            *c /= n as f32;
        }
        for i in 0..n {
            let row = hashed.row_mut(i);
            for (v, &c) in row.iter_mut().zip(center.iter()) {
                *v -= c;
            }
        }
    }
    Ok(Preprocessed { data: ds, hashed, space, center, norms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::{dot_f64, norm2};
    use crate::data::synth::SynthSpec;

    #[test]
    fn linreg_embedding_inner_product_is_residual() {
        let ds = SynthSpec::power_law("t", 50, 8, 1).generate().unwrap();
        let p = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let theta: Vec<f32> = (0..8).map(|j| 0.1 * j as f32).collect();
        let mut q = Vec::new();
        p.query(&theta, &mut q);
        assert_eq!(q.len(), 9);
        for i in 0..p.data.len() {
            let (xi, yi) = p.data.example(i);
            let residual = dot_f64(xi, &theta) - yi as f64;
            let ip = dot_f64(p.hashed.row(i), &q);
            assert!((ip - residual).abs() < 1e-5, "example {i}: {ip} vs {residual}");
        }
    }

    #[test]
    fn logreg_embedding_matches_eq11() {
        let ds = SynthSpec {
            task: Task::Classification,
            ..SynthSpec::power_law("c", 40, 6, 2)
        };
        let ds = ds.generate().unwrap();
        let p = preprocess(ds, &PreprocessOptions::default()).unwrap();
        assert_eq!(p.hashed.cols(), 6);
        let theta: Vec<f32> = vec![0.3; 6];
        let mut q = Vec::new();
        p.query(&theta, &mut q);
        for i in 0..p.data.len() {
            let (xi, yi) = p.data.example(i);
            // ⟨q, v_i⟩ = −y_i θ·x_i
            let want = -(yi as f64) * dot_f64(xi, &theta);
            let got = dot_f64(p.hashed.row(i), &q);
            assert!((got - want).abs() < 1e-5);
        }
    }

    #[test]
    fn features_unit_norm_after_preprocess() {
        let ds = SynthSpec::uniform_control("u", 30, 5, 3).generate().unwrap();
        let p = preprocess(ds, &PreprocessOptions::default()).unwrap();
        for i in 0..p.data.len() {
            assert!((norm2(p.data.x.row(i)) - 1.0).abs() < 1e-5);
        }
        assert_eq!(p.norms.len(), 30);
    }

    #[test]
    fn centering_zeroes_the_mean() {
        let ds = SynthSpec::power_law("t", 64, 8, 4).generate().unwrap();
        let p = preprocess(ds, &PreprocessOptions { center: true }).unwrap();
        assert_eq!(p.center.len(), 9);
        let n = p.data.len();
        for j in 0..p.hashed.cols() {
            let mean: f64 = (0..n).map(|i| p.hashed.get(i, j) as f64).sum::<f64>() / n as f64;
            assert!(mean.abs() < 1e-4, "col {j} mean {mean}");
        }
    }
}
