//! Synthetic sentence-pair classification tasks — the MRPC/RTE stand-ins
//! for the §3.2 BERT experiments.
//!
//! Each example is a token sequence `[CLS] a… [SEP] b…` over a small
//! vocabulary. Segments are drawn from latent "topics" (Zipf unigram
//! distributions with topic-specific offsets); the label says whether the
//! two segments share a topic (paraphrase/entailment analogue). This gives
//! a real learnable signal to the mini transformer while matching the
//! GLUE tasks' size (§Table 4: MRPC 3.7k / RTE 2.5k training pairs).

use crate::core::error::{Error, Result};
use crate::core::rng::{Pcg64, Rng};

/// Reserved token ids.
pub const PAD: i32 = 0;
/// Sequence-start token ([CLS]).
pub const CLS: i32 = 1;
/// Segment separator.
pub const SEP: i32 = 2;
const RESERVED: usize = 3;

/// A generated sequence-classification dataset.
#[derive(Debug, Clone)]
pub struct SeqDataset {
    /// Token ids, row-major (n × max_t).
    pub ids: Vec<i32>,
    /// Labels in {0, 1}.
    pub labels: Vec<i32>,
    /// Sequence length (fixed).
    pub max_t: usize,
    /// Vocabulary size the ids respect.
    pub vocab: usize,
    /// Dataset name.
    pub name: String,
}

/// Generator spec.
#[derive(Debug, Clone)]
pub struct SeqSpec {
    /// Dataset name.
    pub name: String,
    /// Number of pairs.
    pub n: usize,
    /// Vocabulary size (≥ 16).
    pub vocab: usize,
    /// Sequence length.
    pub max_t: usize,
    /// Number of latent topics.
    pub topics: usize,
    /// Zipf exponent of within-topic unigram distributions.
    pub zipf: f64,
    /// Label noise (probability of flipping).
    pub label_noise: f64,
    /// Seed.
    pub seed: u64,
}

impl SeqSpec {
    /// MRPC-sized task (3,669 train pairs in the paper's split).
    pub fn mrpc_like(scale: f64, vocab: usize, max_t: usize, seed: u64) -> Self {
        SeqSpec {
            name: "mrpc-like".into(),
            n: ((3_669.0 * scale) as usize).max(64),
            vocab,
            max_t,
            topics: 8,
            zipf: 1.1,
            label_noise: 0.05,
            seed,
        }
    }

    /// RTE-sized task (2,491 train pairs).
    pub fn rte_like(scale: f64, vocab: usize, max_t: usize, seed: u64) -> Self {
        SeqSpec {
            name: "rte-like".into(),
            n: ((2_491.0 * scale) as usize).max(64),
            vocab,
            max_t,
            topics: 6,
            zipf: 1.3,
            label_noise: 0.08,
            seed,
        }
    }

    /// Generate the dataset.
    pub fn generate(&self) -> SeqDataset {
        assert!(self.vocab >= RESERVED + self.topics * 4, "vocab too small");
        assert!(self.max_t >= 8);
        let mut rng = Pcg64::new(self.seed, 0x53455154); // "SEQT"
        let usable = self.vocab - RESERVED;
        let per_topic = usable / self.topics;
        let mut ids = Vec::with_capacity(self.n * self.max_t);
        let mut labels = Vec::with_capacity(self.n);
        let seg = (self.max_t - 2) / 2;
        for _ in 0..self.n {
            let label = rng.bernoulli(0.5) as i32;
            let t_a = rng.index(self.topics);
            let t_b = if label == 1 {
                t_a
            } else {
                // a different topic
                let mut t = rng.index(self.topics);
                while t == t_a {
                    t = rng.index(self.topics);
                }
                t
            };
            let observed = if rng.bernoulli(self.label_noise) { 1 - label } else { label };
            ids.push(CLS);
            for _ in 0..seg {
                ids.push(self.draw_token(&mut rng, t_a, per_topic));
            }
            ids.push(SEP);
            for _ in 0..seg {
                ids.push(self.draw_token(&mut rng, t_b, per_topic));
            }
            // pad to max_t
            while ids.len() % self.max_t != 0 {
                ids.push(PAD);
            }
            labels.push(observed);
        }
        SeqDataset {
            ids,
            labels,
            max_t: self.max_t,
            vocab: self.vocab,
            name: self.name.clone(),
        }
    }

    fn draw_token(&self, rng: &mut Pcg64, topic: usize, per_topic: usize) -> i32 {
        // Zipf over the topic's token range via inverse-power rejection-free
        // approximation: rank r with prob ∝ 1/r^zipf.
        let u = rng.next_f64();
        let r = ((per_topic as f64).powf(1.0 - self.zipf) * u
            + (1.0 - u))
            .powf(1.0 / (1.0 - self.zipf))
            .floor() as usize;
        let r = r.min(per_topic - 1);
        (RESERVED + topic * per_topic + r) as i32
    }
}

impl SeqDataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Token row of example `i`.
    pub fn row(&self, i: usize) -> &[i32] {
        &self.ids[i * self.max_t..(i + 1) * self.max_t]
    }

    /// Split indices into (train, test). Both sides are guaranteed
    /// non-empty; datasets with fewer than two examples are rejected
    /// (mirroring [`crate::data::Dataset::split`]) instead of silently
    /// producing an empty test side.
    pub fn split(&self, train_frac: f64, seed: u64) -> Result<(Vec<usize>, Vec<usize>)> {
        if !(0.0..1.0).contains(&train_frac) || train_frac == 0.0 {
            return Err(Error::Data(format!("bad train fraction {train_frac}")));
        }
        let n = self.len();
        if n < 2 {
            return Err(Error::Data(format!(
                "sequence dataset has {n} example(s) — at least 2 are needed for a \
                 non-empty train/test split"
            )));
        }
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Pcg64::new(seed, 0x53505456);
        rng.shuffle(&mut idx);
        let k = ((n as f64) * train_frac).round() as usize;
        let k = k.clamp(1, n - 1);
        Ok((idx[..k].to_vec(), idx[k..].to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_tokens() {
        let ds = SeqSpec::mrpc_like(0.1, 256, 32, 1).generate();
        assert!(ds.len() >= 64);
        for i in 0..ds.len() {
            let row = ds.row(i);
            assert_eq!(row.len(), 32);
            assert_eq!(row[0], CLS);
            assert!(row.iter().all(|&t| t >= 0 && (t as usize) < 256));
        }
        assert!(ds.labels.iter().all(|&l| l == 0 || l == 1));
    }

    #[test]
    fn labels_roughly_balanced() {
        let ds = SeqSpec::rte_like(1.0, 256, 32, 3).generate();
        let pos: usize = ds.labels.iter().map(|&l| l as usize).sum();
        let frac = pos as f64 / ds.len() as f64;
        assert!((frac - 0.5).abs() < 0.05, "label balance {frac}");
    }

    #[test]
    fn same_topic_pairs_share_tokens_more() {
        // The signal must exist: token overlap between segments should be
        // higher for label-1 pairs.
        let spec = SeqSpec { label_noise: 0.0, ..SeqSpec::mrpc_like(0.5, 256, 32, 5) };
        let ds = spec.generate();
        let seg = (32 - 2) / 2;
        let mut overlap = [0.0f64; 2];
        let mut count = [0usize; 2];
        for i in 0..ds.len() {
            let row = ds.row(i);
            let a: std::collections::HashSet<i32> = row[1..1 + seg].iter().copied().collect();
            let b: std::collections::HashSet<i32> =
                row[2 + seg..2 + 2 * seg].iter().copied().collect();
            let inter = a.intersection(&b).count() as f64;
            let l = ds.labels[i] as usize;
            overlap[l] += inter;
            count[l] += 1;
        }
        let o0 = overlap[0] / count[0] as f64;
        let o1 = overlap[1] / count[1] as f64;
        assert!(o1 > 2.0 * o0, "overlap same-topic {o1} vs diff-topic {o0}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SeqSpec::mrpc_like(0.05, 128, 16, 7).generate();
        let b = SeqSpec::mrpc_like(0.05, 128, 16, 7).generate();
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn split_partitions() {
        let ds = SeqSpec::mrpc_like(0.1, 128, 16, 9).generate();
        let (tr, te) = ds.split(0.8, 1).unwrap();
        assert_eq!(tr.len() + te.len(), ds.len());
        let mut all: Vec<usize> = tr.iter().chain(te.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..ds.len()).collect::<Vec<_>>());
    }

    /// n ∈ {0, 1} must error (no silent empty test side); n = 2 splits 1/1
    /// at every fraction — the same boundary contract as `Dataset::split`.
    #[test]
    fn split_rejects_too_small_datasets() {
        let mk = |n: usize| SeqDataset {
            ids: vec![CLS; n * 4],
            labels: vec![0; n],
            max_t: 4,
            vocab: 8,
            name: "tiny".into(),
        };
        for n in [0usize, 1] {
            assert!(mk(n).split(0.8, 1).is_err(), "n = {n} must not split");
        }
        for frac in [0.1, 0.5, 0.9] {
            let (tr, te) = mk(2).split(frac, 1).unwrap();
            assert_eq!((tr.len(), te.len()), (1, 1), "n = 2 at frac {frac}");
        }
        assert!(mk(10).split(0.0, 1).is_err());
        assert!(mk(10).split(1.0, 1).is_err());
    }
}
