//! Synthetic workload generators — the data substitution layer.
//!
//! The paper evaluates on three UCI regression sets (YearPredictionMSD,
//! Slice, UJIIndoorLoc) that are not available in this offline environment.
//! LGD's advantage over SGD depends only on the *shape* of the per-example
//! gradient-norm distribution (Lemma 1 is proved under a power-law / Pareto
//! assumption on collision probabilities, and §2.3 predicts parity when the
//! data is uniform). These generators therefore plant:
//!
//! * a cluster mixture over feature directions with Zipf-distributed
//!   cluster masses (real data is directionally clumped — that is what
//!   gives LSH buckets their signal), and
//! * heavy-tailed (signed-Pareto) label noise on a small fraction of
//!   examples, producing the few-large-many-small gradient profile of §2.3,
//!
//! matched to each paper dataset's (N, d). A Gaussian "uniform" control
//! reproduces the predicted LGD ≈ SGD parity regime.

use crate::core::error::Result;
use crate::core::matrix::{normalize, Matrix};
use crate::core::rng::{Pcg64, Rng};
use crate::data::dataset::{Dataset, Task};

/// Specification of a synthetic regression/classification workload.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Dataset name (experiment logs, CSV outputs).
    pub name: String,
    /// Number of examples.
    pub n: usize,
    /// Feature dimensionality.
    pub d: usize,
    /// Number of direction clusters (1 = isotropic).
    pub clusters: usize,
    /// Zipf exponent over cluster masses (0 = uniform masses).
    pub cluster_zipf: f64,
    /// Within-cluster angular spread (stddev of the Gaussian perturbation).
    pub spread: f64,
    /// Base label noise stddev.
    pub noise: f64,
    /// Fraction of examples carrying heavy-tailed extra label noise.
    pub heavy_frac: f64,
    /// Pareto shape for the heavy component (smaller = heavier tail).
    pub heavy_alpha: f64,
    /// Task type.
    pub task: Task,
    /// RNG seed.
    pub seed: u64,
}

impl SynthSpec {
    /// Power-law workload matched to a paper dataset's (n, d).
    pub fn power_law(name: &str, n: usize, d: usize, seed: u64) -> Self {
        SynthSpec {
            name: name.into(),
            n,
            d,
            clusters: 32,
            cluster_zipf: 1.2,
            spread: 0.25,
            noise: 0.05,
            heavy_frac: 0.05,
            // α = 2.5: heavy tail with finite variance — matches the paper's
            // few-large-many-small gradient profile without the infinite-
            // second-moment pathology of α ≤ 2.
            heavy_alpha: 2.5,
            task: Task::Regression,
            seed,
        }
    }

    /// Uniform/Gaussian control: isotropic directions, homoscedastic noise —
    /// the regime where §2.3 predicts Tr Σ(LGD) ≈ Tr Σ(SGD).
    pub fn uniform_control(name: &str, n: usize, d: usize, seed: u64) -> Self {
        SynthSpec {
            name: name.into(),
            n,
            d,
            clusters: 1,
            cluster_zipf: 0.0,
            spread: 1.0,
            noise: 0.1,
            heavy_frac: 0.0,
            heavy_alpha: 2.0,
            task: Task::Regression,
            seed,
        }
    }

    /// Generate the dataset (features unit-normalised, as §2.2 requires).
    pub fn generate(&self) -> Result<Dataset> {
        assert!(self.n > 0 && self.d > 0);
        let mut rng = Pcg64::new(self.seed, 0x53594e54); // "SYNT"

        // Planted parameter.
        let mut theta_star: Vec<f32> = (0..self.d).map(|_| rng.gaussian() as f32).collect();
        normalize(&mut theta_star);

        // Cluster centers + Zipf masses.
        let c = self.clusters.max(1);
        let mut centers: Vec<Vec<f32>> = Vec::with_capacity(c);
        for _ in 0..c {
            let mut v: Vec<f32> = (0..self.d).map(|_| rng.gaussian() as f32).collect();
            normalize(&mut v);
            centers.push(v);
        }
        let mut masses: Vec<f64> = (1..=c)
            .map(|r| 1.0 / (r as f64).powf(self.cluster_zipf))
            .collect();
        let z: f64 = masses.iter().sum();
        for m in masses.iter_mut() {
            *m /= z;
        }
        // Cumulative for sampling.
        let mut cum = Vec::with_capacity(c);
        let mut acc = 0.0;
        for &m in &masses {
            acc += m;
            cum.push(acc);
        }

        let mut x = Matrix::zeros(0, 0);
        let mut y = Vec::with_capacity(self.n);
        let mut row = vec![0.0f32; self.d];
        for _ in 0..self.n {
            // Pick cluster by mass.
            let u = rng.next_f64();
            let ci = cum.iter().position(|&cv| u <= cv).unwrap_or(c - 1);
            for j in 0..self.d {
                row[j] = centers[ci][j] + (self.spread * rng.gaussian()) as f32;
            }
            normalize(&mut row);
            let mut target = crate::core::matrix::dot_f64(&row, &theta_star);
            target += self.noise * rng.gaussian();
            if self.heavy_frac > 0.0 && rng.bernoulli(self.heavy_frac) {
                // Signed Pareto excess: the few-large-gradients population.
                let mag = rng.pareto(0.5, self.heavy_alpha);
                target += rng.rademacher() * mag;
            }
            let yv = match self.task {
                Task::Regression => target as f32,
                Task::Classification => {
                    if target >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                }
            };
            x.push_row(&row).map_err(|e| crate::core::error::Error::Data(e.to_string()))?;
            y.push(yv);
        }
        Dataset::new(self.name.clone(), x, y, self.task)
    }
}

/// The five paper-matched workloads (Table 4), at a configurable scale
/// factor so unit tests and full experiment runs share one code path.
/// `scale = 1.0` reproduces the paper's N exactly.
pub fn paper_specs(scale: f64, seed: u64) -> Vec<SynthSpec> {
    let s = |n: usize| ((n as f64 * scale).round() as usize).max(64);
    vec![
        SynthSpec::power_law("yearmsd-like", s(463_715), 90, seed ^ 1),
        SynthSpec::power_law("slice-like", s(53_500), 385, seed ^ 2),
        SynthSpec::power_law("ujiindoor-like", s(21_048), 529, seed ^ 3),
        // NLP-task stand-ins for the BERT experiments (classification).
        SynthSpec {
            task: Task::Classification,
            ..SynthSpec::power_law("mrpc-like", s(4_078), 64, seed ^ 4)
        },
        SynthSpec {
            task: Task::Classification,
            ..SynthSpec::power_law("rte-like", s(2_769), 64, seed ^ 5)
        },
    ]
}

/// Per-example gradient L2 norms of least squares at `theta` — used by the
/// generators' own validation and by the variance experiments.
pub fn linreg_grad_norms(ds: &Dataset, theta: &[f32]) -> Vec<f64> {
    (0..ds.len())
        .map(|i| {
            let (xi, yi) = ds.example(i);
            let r = crate::core::matrix::dot_f64(xi, theta) - yi as f64;
            2.0 * r.abs() * crate::core::matrix::norm2(xi)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::stats;

    #[test]
    fn generate_shapes_and_determinism() {
        let spec = SynthSpec::power_law("t", 200, 16, 9);
        let a = spec.generate().unwrap();
        let b = spec.generate().unwrap();
        assert_eq!(a.len(), 200);
        assert_eq!(a.dim(), 16);
        assert_eq!(a.y, b.y, "same seed must give identical data");
        let spec2 = SynthSpec::power_law("t", 200, 16, 10);
        assert_ne!(spec2.generate().unwrap().y, a.y);
    }

    #[test]
    fn rows_are_unit_norm() {
        let ds = SynthSpec::power_law("t", 100, 12, 3).generate().unwrap();
        for i in 0..ds.len() {
            let n = crate::core::matrix::norm2(ds.x.row(i));
            assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
        }
    }

    /// The planted heavy tail must show up in the gradient-norm profile:
    /// power-law spec ⇒ max/median norm ratio far larger than control.
    #[test]
    fn power_law_has_heavier_gradient_tail_than_control() {
        let d = 24;
        let pl = SynthSpec::power_law("pl", 2_000, d, 7).generate().unwrap();
        let ctl = SynthSpec::uniform_control("ctl", 2_000, d, 7).generate().unwrap();
        // random theta mimicking an intermediate iterate
        let mut rng = Pcg64::seeded(1);
        let mut theta: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        normalize(&mut theta);
        let g_pl = linreg_grad_norms(&pl, &theta);
        let g_ctl = linreg_grad_norms(&ctl, &theta);
        let ratio_pl = stats::quantile(&g_pl, 1.0) / stats::median(&g_pl).max(1e-12);
        let ratio_ctl = stats::quantile(&g_ctl, 1.0) / stats::median(&g_ctl).max(1e-12);
        assert!(
            ratio_pl > 2.0 * ratio_ctl,
            "power-law tail ratio {ratio_pl} vs control {ratio_ctl}"
        );
    }

    #[test]
    fn classification_labels_are_pm_one() {
        let spec = SynthSpec {
            task: Task::Classification,
            ..SynthSpec::power_law("c", 300, 10, 5)
        };
        let ds = spec.generate().unwrap();
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 30 && pos < 270, "labels degenerate: {pos} positive");
    }

    #[test]
    fn paper_specs_match_table4_at_full_scale() {
        let specs = paper_specs(1.0, 0);
        assert_eq!(specs.len(), 5);
        assert_eq!(specs[0].n, 463_715);
        assert_eq!(specs[0].d, 90);
        assert_eq!(specs[1].n, 53_500);
        assert_eq!(specs[2].d, 529);
        // scaled down for tests
        let small = paper_specs(0.001, 0);
        assert!(small[0].n >= 64 && small[0].n < 1000);
    }
}
