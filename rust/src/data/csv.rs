//! Minimal numeric-CSV reader/writer.
//!
//! The loader accepts the UCI-style layout the paper's datasets ship in
//! (plain numeric CSV, configurable target column) so real data drops in if
//! present; the writer emits the experiment result series consumed by
//! EXPERIMENTS.md and external plotting.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::core::error::{Error, Result};
use crate::core::matrix::Matrix;
use crate::data::dataset::{Dataset, Task};

/// Which column holds the target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetColumn {
    /// First column (YearPredictionMSD layout).
    First,
    /// Last column (Slice / UJIIndoorLoc layout).
    Last,
    /// Explicit zero-based index.
    Index(usize),
}

/// Load a numeric CSV into a dataset. Blank lines are skipped; a first line
/// containing any non-numeric cell is treated as a header and skipped.
/// Non-finite cells (`nan`, `inf`, `-inf` — which `f32::parse` happily
/// accepts) are rejected with a line-numbered error: one poisoned row
/// corrupts row norms, hash codes and every gradient downstream, long
/// before the health sentinels could attribute it. Use [`load_csv_with`]
/// with `allow_nonfinite = true` (`data.allow_nonfinite`) to opt out.
pub fn load_csv(path: &Path, target: TargetColumn, task: Task) -> Result<Dataset> {
    load_csv_with(path, target, task, false)
}

/// [`load_csv`] with the non-finite gate exposed (`data.allow_nonfinite`).
pub fn load_csv_with(
    path: &Path,
    target: TargetColumn,
    task: Task,
    allow_nonfinite: bool,
) -> Result<Dataset> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
    let reader = std::io::BufReader::new(file);
    let mut x = Matrix::zeros(0, 0);
    let mut y = Vec::new();
    let mut width: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| Error::Io(format!("{}:{lineno}: {e}", path.display())))?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(|c| c.trim()).collect();
        let parsed: std::result::Result<Vec<f32>, _> =
            cells.iter().map(|c| c.parse::<f32>()).collect();
        let vals = match parsed {
            Ok(v) => v,
            Err(_) if lineno == 0 => continue, // header
            Err(e) => {
                return Err(Error::Data(format!(
                    "{}:{}: non-numeric cell: {e}",
                    path.display(),
                    lineno + 1
                )))
            }
        };
        if !allow_nonfinite {
            if let Some(j) = vals.iter().position(|v| !v.is_finite()) {
                return Err(Error::Data(format!(
                    "{}:{}: non-finite cell '{}' in column {} (set \
                     data.allow_nonfinite to accept)",
                    path.display(),
                    lineno + 1,
                    cells[j],
                    j
                )));
            }
        }
        if let Some(w) = width {
            if vals.len() != w {
                return Err(Error::Data(format!(
                    "{}:{}: {} cells, expected {w}",
                    path.display(),
                    lineno + 1,
                    vals.len()
                )));
            }
        } else {
            if vals.len() < 2 {
                return Err(Error::Data("need at least 2 columns".into()));
            }
            width = Some(vals.len());
        }
        let ti = match target {
            TargetColumn::First => 0,
            TargetColumn::Last => vals.len() - 1,
            TargetColumn::Index(i) => {
                if i >= vals.len() {
                    return Err(Error::Data(format!("target column {i} out of range")));
                }
                i
            }
        };
        y.push(vals[ti]);
        let feats: Vec<f32> = vals
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != ti)
            .map(|(_, &v)| v)
            .collect();
        x.push_row(&feats).map_err(|e| Error::Data(e.to_string()))?;
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    Dataset::new(name, x, y, task)
}

/// Incremental CSV writer for experiment series.
pub struct CsvWriter {
    out: BufWriter<std::fs::File>,
    cols: usize,
}

impl CsvWriter {
    /// Create (truncating) with a header row.
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)
            .map_err(|e| Error::Io(format!("{}: {e}", path.display())))?;
        let mut out = BufWriter::new(f);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write one row of f64 cells (formatted compactly).
    pub fn row(&mut self, cells: &[f64]) -> Result<()> {
        if cells.len() != self.cols {
            return Err(Error::Data(format!(
                "csv row of {} cells, header had {}",
                cells.len(),
                self.cols
            )));
        }
        let s: Vec<String> = cells.iter().map(|v| format!("{v}")).collect();
        writeln!(self.out, "{}", s.join(","))?;
        Ok(())
    }

    /// Write one row of mixed string cells.
    pub fn row_str(&mut self, cells: &[String]) -> Result<()> {
        if cells.len() != self.cols {
            return Err(Error::Data("csv row width mismatch".into()));
        }
        writeln!(self.out, "{}", cells.join(","))?;
        Ok(())
    }

    /// Flush to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lgd-csv-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_last_target() {
        let p = tmpfile("rt.csv");
        std::fs::write(&p, "a,b,y\n1,2,3\n4,5,6\n").unwrap();
        let ds = load_csv(&p, TargetColumn::Last, Task::Regression).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.y, vec![3.0, 6.0]);
        assert_eq!(ds.x.row(1), &[4.0, 5.0]);
    }

    #[test]
    fn first_target_yearmsd_layout() {
        let p = tmpfile("first.csv");
        std::fs::write(&p, "2001,0.5,0.25\n1999,1.5,2.5\n").unwrap();
        let ds = load_csv(&p, TargetColumn::First, Task::Regression).unwrap();
        assert_eq!(ds.y, vec![2001.0, 1999.0]);
        assert_eq!(ds.x.row(0), &[0.5, 0.25]);
    }

    #[test]
    fn ragged_rows_rejected() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&p, TargetColumn::Last, Task::Regression).is_err());
    }

    #[test]
    fn non_numeric_mid_file_rejected() {
        let p = tmpfile("bad.csv");
        std::fs::write(&p, "1,2\n3,x\n").unwrap();
        assert!(load_csv(&p, TargetColumn::Last, Task::Regression).is_err());
    }

    /// `f32::parse` accepts `nan`/`inf` spellings, so without the explicit
    /// gate a poisoned row loads silently. Each fixture must fail with the
    /// 1-based line number and column; the escape hatch loads them all.
    #[test]
    fn non_finite_cells_rejected_with_line_numbers() {
        let fixtures = [
            ("nanfeat.csv", "1,2,3\n4,NaN,6\n", "2", "column 1"),
            ("inftarget.csv", "1,2,3\n4,5,inf\n", "2", "column 2"),
            ("mixed.csv", "1,2,3\n-inf,nan,6\n", "2", "column 0"),
        ];
        for (name, body, line, col) in fixtures {
            let p = tmpfile(name);
            std::fs::write(&p, body).unwrap();
            let err = load_csv(&p, TargetColumn::Last, Task::Regression).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(&format!(":{line}:")), "{name}: no line number in {msg}");
            assert!(msg.contains(col), "{name}: no column in {msg}");
            // escape hatch: same file loads, non-finite values preserved
            let ds = load_csv_with(&p, TargetColumn::Last, Task::Regression, true).unwrap();
            assert_eq!(ds.len(), 2);
            assert!(
                ds.y.iter().any(|v| !v.is_finite())
                    || (0..ds.len()).any(|i| ds.x.row(i).iter().any(|v| !v.is_finite())),
                "{name}: escape hatch dropped the non-finite cell"
            );
        }
    }

    #[test]
    fn writer_emits_header_and_rows() {
        let p = tmpfile("w.csv");
        {
            let mut w = CsvWriter::create(&p, &["epoch", "loss"]).unwrap();
            w.row(&[0.0, 1.5]).unwrap();
            w.row(&[1.0, 0.75]).unwrap();
            assert!(w.row(&[1.0]).is_err());
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "epoch,loss");
        assert_eq!(lines.len(), 3);
    }
}
