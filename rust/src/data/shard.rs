//! Hash-sharding of datasets across pipeline workers.
//!
//! The streaming coordinator partitions incoming records across parallel
//! hash-build workers; each worker owns a shard of ids and inserts them into
//! its slice of the L tables (table-parallel building). Rebalancing moves
//! whole shards, never single records, so build workers stay cache-friendly.

use crate::core::error::{Error, Result};

/// A shard assignment: `shard_of[i]` = worker owning record i. Keeps
/// per-shard member lists alongside the flat assignment so rebalancing
/// moves are O(1) per migrated id (no O(n) `position` scan — the ROADMAP
/// rebalance-cost item).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    shards: usize,
    shard_of: Vec<u32>,
    counts: Vec<usize>,
    /// Ids owned by each shard (insertion order; swap-mutated by
    /// `rebalance`, so not sorted after moves).
    members: Vec<Vec<u32>>,
}

impl ShardPlan {
    fn build_members(shards: usize, shard_of: &[u32]) -> Vec<Vec<u32>> {
        let mut members = vec![Vec::new(); shards];
        for (i, &s) in shard_of.iter().enumerate() {
            members[s as usize].push(i as u32);
        }
        members
    }

    /// Round-robin plan over `n` records and `shards` workers.
    pub fn round_robin(n: usize, shards: usize) -> Result<Self> {
        if shards == 0 {
            return Err(Error::Data("zero shards".into()));
        }
        let shard_of: Vec<u32> = (0..n).map(|i| (i % shards) as u32).collect();
        let mut counts = vec![0usize; shards];
        for &s in &shard_of {
            counts[s as usize] += 1;
        }
        let members = Self::build_members(shards, &shard_of);
        Ok(ShardPlan { shards, shard_of, counts, members })
    }

    /// Wrap an explicit assignment vector (`shard_of[i]` = shard owning
    /// id `i`). Live resharding uses this to rebalance the *current*
    /// membership of a mutated shard set; tests use it to construct
    /// deliberately skewed plans.
    pub fn from_assignments(shards: usize, shard_of: Vec<u32>) -> Result<Self> {
        if shards == 0 {
            return Err(Error::Data("zero shards".into()));
        }
        let mut counts = vec![0usize; shards];
        for &s in &shard_of {
            if s as usize >= shards {
                return Err(Error::Data(format!(
                    "assignment to shard {s} but only {shards} shards"
                )));
            }
            counts[s as usize] += 1;
        }
        let members = Self::build_members(shards, &shard_of);
        Ok(ShardPlan { shards, shard_of, counts, members })
    }

    /// Multiplicative-hash plan (stable under reordering of the input).
    pub fn hashed(n: usize, shards: usize) -> Result<Self> {
        if shards == 0 {
            return Err(Error::Data("zero shards".into()));
        }
        let shard_of: Vec<u32> = (0..n as u64)
            .map(|i| {
                let h = i.wrapping_mul(0x9E3779B97F4A7C15) >> 33;
                (h % shards as u64) as u32
            })
            .collect();
        let mut counts = vec![0usize; shards];
        for &s in &shard_of {
            counts[s as usize] += 1;
        }
        let members = Self::build_members(shards, &shard_of);
        Ok(ShardPlan { shards, shard_of, counts, members })
    }

    /// Worker for record `i`.
    pub fn shard_of(&self, i: usize) -> usize {
        self.shard_of[i] as usize
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Records per shard.
    pub fn counts(&self) -> &[usize] {
        &self.counts
    }

    /// Ids owned by `shard` (O(1) — the maintained member list; ascending
    /// for fresh plans, swap-mutated order after `rebalance`).
    pub fn members(&self, shard: usize) -> &[u32] {
        &self.members[shard]
    }

    /// Imbalance = max/mean shard size (1.0 is perfect).
    pub fn imbalance(&self) -> f64 {
        let max = *self.counts.iter().max().unwrap_or(&0) as f64;
        let mean = self.shard_of.len() as f64 / self.shards as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Rebalance: move records from the largest shard(s) to the smallest
    /// until imbalance ≤ `target` (or no move helps — `max ≤ min + 1`
    /// breaks out immediately, so an unreachable target never burns a
    /// pass). Each move pops the fullest shard's member list: O(1) per
    /// migrated id. Returns moves performed as (id, from, to).
    pub fn rebalance(&mut self, target: f64) -> Vec<(usize, usize, usize)> {
        let mut moves = Vec::new();
        loop {
            if self.imbalance() <= target {
                break;
            }
            let (max_s, _) = self.counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap();
            let (min_s, _) = self.counts.iter().enumerate().min_by_key(|(_, &c)| c).unwrap();
            if self.counts[max_s] <= self.counts[min_s] + 1 {
                break; // nothing useful to move
            }
            // move the most recently listed record from max to min (O(1))
            let id = match self.members[max_s].pop() {
                Some(id) => id,
                None => break,
            };
            self.shard_of[id as usize] = min_s as u32;
            self.members[min_s].push(id);
            self.counts[max_s] -= 1;
            self.counts[min_s] += 1;
            moves.push((id as usize, max_s, min_s));
        }
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_is_balanced() {
        let p = ShardPlan::round_robin(100, 4).unwrap();
        assert_eq!(p.counts(), &[25, 25, 25, 25]);
        assert!((p.imbalance() - 1.0).abs() < 1e-9);
        assert_eq!(p.shard_of(5), 1);
    }

    #[test]
    fn hashed_covers_all_and_roughly_balances() {
        let p = ShardPlan::hashed(10_000, 8).unwrap();
        let total: usize = p.counts().iter().sum();
        assert_eq!(total, 10_000);
        assert!(p.imbalance() < 1.2, "imbalance {}", p.imbalance());
    }

    #[test]
    fn members_partition_ids() {
        let p = ShardPlan::hashed(500, 3).unwrap();
        let mut all: Vec<u32> = (0..3).flat_map(|s| p.members(s).iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..500u32).collect::<Vec<_>>());
    }

    #[test]
    fn rebalance_reduces_imbalance() {
        // deliberately skewed: everything on shard 0
        let mut p = ShardPlan::from_assignments(3, vec![0u32; 60]).unwrap();
        assert!(p.imbalance() > 2.9);
        let moves = p.rebalance(1.1);
        assert!(!moves.is_empty());
        assert!(p.imbalance() <= 1.1, "imbalance {}", p.imbalance());
        let total: usize = p.counts().iter().sum();
        assert_eq!(total, 60);
        // member lists track the moves exactly
        for s in 0..3 {
            assert_eq!(p.members(s).len(), p.counts()[s]);
            for &id in p.members(s) {
                assert_eq!(p.shard_of(id as usize), s, "member list desynced");
            }
        }
    }

    #[test]
    fn zero_shards_rejected() {
        assert!(ShardPlan::round_robin(10, 0).is_err());
        assert!(ShardPlan::hashed(10, 0).is_err());
        assert!(ShardPlan::from_assignments(0, Vec::new()).is_err());
    }

    #[test]
    fn from_assignments_validates_and_counts() {
        let p = ShardPlan::from_assignments(3, vec![0, 2, 2, 1, 2]).unwrap();
        assert_eq!(p.counts(), &[1, 1, 3]);
        assert_eq!(p.shard_of(4), 2);
        assert!(ShardPlan::from_assignments(2, vec![0, 2]).is_err(), "out-of-range shard");
    }

    /// Random, often heavily skewed assignment for the property tests.
    fn random_assignment(rng: &mut crate::core::rng::Pcg64) -> (usize, Vec<u32>) {
        use crate::core::rng::Rng;
        let shards = 1 + rng.index(6);
        // includes the degenerate n = 0, n < shards and shards = 1 cases
        let n = rng.index(80);
        let skew = rng.bernoulli(0.5);
        let assign: Vec<u32> = (0..n)
            .map(|_| {
                if skew && rng.bernoulli(0.7) {
                    0
                } else {
                    rng.index(shards) as u32
                }
            })
            .collect();
        (shards, assign)
    }

    /// Property: `rebalance` preserves the membership partition — every id
    /// stays in exactly one shard, counts recount exactly and sum to n —
    /// never increases `imbalance()`, and is a no-op (zero moves, identical
    /// assignment) when the plan is already under target.
    #[test]
    fn prop_rebalance_preserves_partition_and_never_worsens() {
        use crate::core::rng::Rng;
        crate::testkit::prop(200, |rng| {
            let (shards, assign) = random_assignment(rng);
            let n = assign.len();
            let mut p = ShardPlan::from_assignments(shards, assign.clone()).unwrap();
            let before = p.imbalance();
            let target = 1.0 + rng.next_f64() * 2.0;
            let moves = p.rebalance(target);
            // partition preserved: counts recount exactly and sum to n
            assert_eq!(p.counts().iter().sum::<usize>(), n);
            let mut recount = vec![0usize; shards];
            for i in 0..n {
                recount[p.shard_of(i)] += 1;
            }
            assert_eq!(&recount, p.counts());
            let members_total: usize = (0..shards).map(|s| p.members(s).len()).sum();
            assert_eq!(members_total, n, "members() must partition the ids");
            for s in 0..shards {
                for &id in p.members(s) {
                    assert_eq!(p.shard_of(id as usize), s, "member list desynced after moves");
                }
            }
            // imbalance never increases
            assert!(
                p.imbalance() <= before + 1e-12,
                "imbalance rose {before} -> {}",
                p.imbalance()
            );
            // no-op when already under target
            if before <= target {
                assert!(moves.is_empty(), "under-target plan must not move ids");
                for (i, &s) in assign.iter().enumerate() {
                    assert_eq!(p.shard_of(i), s as usize, "no-op rebalance changed id {i}");
                }
            }
        });
    }

    /// Property: rebalancing to target 1.0 reaches the fully balanced state
    /// (max − min ≤ 1), and the reported move list replays exactly onto the
    /// original assignment — the contract live shard migration relies on.
    #[test]
    fn prop_rebalance_to_one_fully_balances_and_moves_replay() {
        crate::testkit::prop(120, |rng| {
            let (shards, assign) = random_assignment(rng);
            let n = assign.len();
            let mut p = ShardPlan::from_assignments(shards, assign.clone()).unwrap();
            let moves = p.rebalance(1.0);
            let max = *p.counts().iter().max().unwrap();
            let min = *p.counts().iter().min().unwrap();
            assert!(max - min <= 1, "not fully balanced: counts {:?}", p.counts());
            let mut replay = assign;
            for &(id, from, to) in &moves {
                assert_eq!(replay[id] as usize, from, "move reports wrong source shard");
                assert!(to < shards);
                replay[id] = to as u32;
            }
            for i in 0..n {
                assert_eq!(replay[i] as usize, p.shard_of(i), "replayed moves diverge at {i}");
            }
        });
    }
}
