//! Hash-sharding of datasets across pipeline workers.
//!
//! The streaming coordinator partitions incoming records across parallel
//! hash-build workers; each worker owns a shard of ids and inserts them into
//! its slice of the L tables (table-parallel building). Rebalancing moves
//! whole shards, never single records, so build workers stay cache-friendly.

use crate::core::error::{Error, Result};

/// A shard assignment: `shard_of[i]` = worker owning record i.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    shards: usize,
    shard_of: Vec<u32>,
    counts: Vec<usize>,
}

impl ShardPlan {
    /// Round-robin plan over `n` records and `shards` workers.
    pub fn round_robin(n: usize, shards: usize) -> Result<Self> {
        if shards == 0 {
            return Err(Error::Data("zero shards".into()));
        }
        let shard_of: Vec<u32> = (0..n).map(|i| (i % shards) as u32).collect();
        let mut counts = vec![0usize; shards];
        for &s in &shard_of {
            counts[s as usize] += 1;
        }
        Ok(ShardPlan { shards, shard_of, counts })
    }

    /// Multiplicative-hash plan (stable under reordering of the input).
    pub fn hashed(n: usize, shards: usize) -> Result<Self> {
        if shards == 0 {
            return Err(Error::Data("zero shards".into()));
        }
        let shard_of: Vec<u32> = (0..n as u64)
            .map(|i| {
                let h = i.wrapping_mul(0x9E3779B97F4A7C15) >> 33;
                (h % shards as u64) as u32
            })
            .collect();
        let mut counts = vec![0usize; shards];
        for &s in &shard_of {
            counts[s as usize] += 1;
        }
        Ok(ShardPlan { shards, shard_of, counts })
    }

    /// Worker for record `i`.
    pub fn shard_of(&self, i: usize) -> usize {
        self.shard_of[i] as usize
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Records per shard.
    pub fn counts(&self) -> &[usize] {
        &self.counts
    }

    /// Ids owned by `shard`.
    pub fn members(&self, shard: usize) -> Vec<usize> {
        self.shard_of
            .iter()
            .enumerate()
            .filter(|(_, &s)| s as usize == shard)
            .map(|(i, _)| i)
            .collect()
    }

    /// Imbalance = max/mean shard size (1.0 is perfect).
    pub fn imbalance(&self) -> f64 {
        let max = *self.counts.iter().max().unwrap_or(&0) as f64;
        let mean = self.shard_of.len() as f64 / self.shards as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Rebalance: move whole id ranges from the largest shard(s) to the
    /// smallest until imbalance ≤ `target` (or no move helps). Returns moves
    /// performed as (id, from, to).
    pub fn rebalance(&mut self, target: f64) -> Vec<(usize, usize, usize)> {
        let mut moves = Vec::new();
        loop {
            if self.imbalance() <= target {
                break;
            }
            let (max_s, _) =
                self.counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap();
            let (min_s, _) =
                self.counts.iter().enumerate().min_by_key(|(_, &c)| c).unwrap();
            if self.counts[max_s] <= self.counts[min_s] + 1 {
                break; // nothing useful to move
            }
            // move one record from max to min
            if let Some(i) = self
                .shard_of
                .iter()
                .position(|&s| s as usize == max_s)
            {
                self.shard_of[i] = min_s as u32;
                self.counts[max_s] -= 1;
                self.counts[min_s] += 1;
                moves.push((i, max_s, min_s));
            } else {
                break;
            }
        }
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_is_balanced() {
        let p = ShardPlan::round_robin(100, 4).unwrap();
        assert_eq!(p.counts(), &[25, 25, 25, 25]);
        assert!((p.imbalance() - 1.0).abs() < 1e-9);
        assert_eq!(p.shard_of(5), 1);
    }

    #[test]
    fn hashed_covers_all_and_roughly_balances() {
        let p = ShardPlan::hashed(10_000, 8).unwrap();
        let total: usize = p.counts().iter().sum();
        assert_eq!(total, 10_000);
        assert!(p.imbalance() < 1.2, "imbalance {}", p.imbalance());
    }

    #[test]
    fn members_partition_ids() {
        let p = ShardPlan::hashed(500, 3).unwrap();
        let mut all: Vec<usize> = (0..3).flat_map(|s| p.members(s)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..500).collect::<Vec<_>>());
    }

    #[test]
    fn rebalance_reduces_imbalance() {
        // deliberately skewed: everything on shard 0
        let mut p = ShardPlan::round_robin(60, 3).unwrap();
        for s in p.shard_of.iter_mut() {
            *s = 0;
        }
        p.counts = vec![60, 0, 0];
        assert!(p.imbalance() > 2.9);
        let moves = p.rebalance(1.1);
        assert!(!moves.is_empty());
        assert!(p.imbalance() <= 1.1, "imbalance {}", p.imbalance());
        let total: usize = p.counts().iter().sum();
        assert_eq!(total, 60);
    }

    #[test]
    fn zero_shards_rejected() {
        assert!(ShardPlan::round_robin(10, 0).is_err());
        assert!(ShardPlan::hashed(10, 0).is_err());
    }
}
