//! In-memory dense dataset with train/test splits.

use crate::core::error::{Error, Result};
use crate::core::matrix::Matrix;
use crate::core::rng::{Pcg64, Rng};

/// Task type a dataset is meant for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Real-valued targets (least squares).
    Regression,
    /// Binary labels in {−1, +1} (logistic regression).
    Classification,
}

/// A dense supervised dataset: features `x` (n × d) and targets `y` (n).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Feature matrix, one row per example.
    pub x: Matrix,
    /// Targets (regression values or ±1 labels).
    pub y: Vec<f32>,
    /// Task type.
    pub task: Task,
    /// Human-readable name (experiment logs).
    pub name: String,
}

impl Dataset {
    /// Construct with validation.
    pub fn new(name: impl Into<String>, x: Matrix, y: Vec<f32>, task: Task) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(Error::Data(format!(
                "{} feature rows but {} targets",
                x.rows(),
                y.len()
            )));
        }
        Ok(Dataset { x, y, task, name: name.into() })
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.x.rows()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Example accessor.
    pub fn example(&self, i: usize) -> (&[f32], f32) {
        (self.x.row(i), self.y[i])
    }

    /// Split into (train, test) by shuffled indices; `train_frac` in (0,1).
    /// Both sides are guaranteed non-empty, so datasets with fewer than two
    /// examples are rejected here — a silent 1/0 "split" would train on
    /// everything and report test loss over nothing.
    pub fn split(&self, train_frac: f64, seed: u64) -> Result<(Dataset, Dataset)> {
        if !(0.0..1.0).contains(&train_frac) || train_frac == 0.0 {
            return Err(Error::Data(format!("bad train fraction {train_frac}")));
        }
        let n = self.len();
        if n < 2 {
            return Err(Error::Data(format!(
                "dataset '{}' has {n} example(s) — at least 2 are needed for a \
                 non-empty train/test split",
                self.name
            )));
        }
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Pcg64::new(seed, 0x53504c54); // "SPLT"
        rng.shuffle(&mut idx);
        let n_train = ((n as f64) * train_frac).round() as usize;
        let n_train = n_train.clamp(1, n - 1);
        let take = |ids: &[usize], tag: &str| -> Result<Dataset> {
            let mut x = Matrix::zeros(0, 0);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.push_row(self.x.row(i)).map_err(|e| Error::Data(e.to_string()))?;
                y.push(self.y[i]);
            }
            Dataset::new(format!("{}-{tag}", self.name), x, y, self.task)
        };
        Ok((take(&idx[..n_train], "train")?, take(&idx[n_train..], "test")?))
    }

    /// Subset by explicit indices (used by sharding).
    pub fn subset(&self, ids: &[usize], tag: &str) -> Result<Dataset> {
        let mut x = Matrix::zeros(0, 0);
        let mut y = Vec::with_capacity(ids.len());
        for &i in ids {
            if i >= self.len() {
                return Err(Error::Data(format!("subset index {i} out of {}", self.len())));
            }
            x.push_row(self.x.row(i)).map_err(|e| Error::Data(e.to_string()))?;
            y.push(self.y[i]);
        }
        Dataset::new(format!("{}-{tag}", self.name), x, y, self.task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, d: usize) -> Dataset {
        let mut x = Matrix::zeros(0, 0);
        for i in 0..n {
            let row: Vec<f32> = (0..d).map(|j| (i * d + j) as f32).collect();
            x.push_row(&row).unwrap();
        }
        let y: Vec<f32> = (0..n).map(|i| i as f32).collect();
        Dataset::new("toy", x, y, Task::Regression).unwrap()
    }

    #[test]
    fn new_validates_lengths() {
        let x = Matrix::from_vec(2, 2, vec![0.0; 4]).unwrap();
        assert!(Dataset::new("bad", x, vec![1.0; 3], Task::Regression).is_err());
    }

    #[test]
    fn split_partitions_exactly() {
        let ds = toy(100, 3);
        let (tr, te) = ds.split(0.8, 7).unwrap();
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.dim(), 3);
        // every original target appears exactly once across the two splits
        let mut all: Vec<f32> = tr.y.iter().chain(te.y.iter()).copied().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, (0..100).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn split_is_seeded() {
        let ds = toy(50, 2);
        let (a, _) = ds.split(0.5, 1).unwrap();
        let (b, _) = ds.split(0.5, 1).unwrap();
        let (c, _) = ds.split(0.5, 2).unwrap();
        assert_eq!(a.y, b.y);
        assert_ne!(a.y, c.y);
    }

    /// The degenerate-split boundary: n = 0 and n = 1 cannot yield two
    /// non-empty sides and must error loudly (the old clamp silently
    /// "split" a singleton into train = everything, test = nothing); n = 2
    /// is the smallest legal dataset and always splits 1/1 regardless of
    /// the fraction.
    #[test]
    fn split_rejects_too_small_datasets() {
        for n in [0usize, 1] {
            let ds = toy(n, 2);
            let err = ds.split(0.8, 1);
            assert!(err.is_err(), "n = {n} must not split");
        }
        let ds = toy(2, 2);
        for frac in [0.1, 0.5, 0.9] {
            let (tr, te) = ds.split(frac, 1).unwrap();
            assert_eq!((tr.len(), te.len()), (1, 1), "n = 2 at frac {frac}");
        }
        // fraction validation is unchanged
        assert!(toy(10, 2).split(0.0, 1).is_err());
        assert!(toy(10, 2).split(1.0, 1).is_err());
    }

    #[test]
    fn subset_checks_bounds() {
        let ds = toy(10, 2);
        assert!(ds.subset(&[0, 11], "s").is_err());
        let s = ds.subset(&[3, 5, 7], "s").unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.y, vec![3.0, 5.0, 7.0]);
    }
}
