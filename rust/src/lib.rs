//! # LGD — LSH-sampled Stochastic Gradient Descent
//!
//! Production-quality reproduction of *"LSH-sampling Breaks the Computation
//! Chicken-and-egg Loop in Adaptive Stochastic Gradient Estimation"*
//! (Chen, Xu & Shrivastava, NeurIPS 2019).
//!
//! Three-layer architecture:
//! * **L3 (this crate)** — the coordination system: LSH tables, the
//!   Algorithm-1 sampler, unbiased estimators, optimizers, the streaming
//!   data pipeline and the experiment drivers. Python never runs here.
//! * **L2 (`python/compile/model.py`)** — JAX compute graphs, AOT-lowered
//!   to HLO text under `artifacts/`.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels called by L2.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod estimator;
pub mod experiments;
pub mod lsh;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod store;
pub mod testkit;

pub use crate::core::error::{Error, Result};
