//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! A property runs over many generated cases; on failure the reporting
//! includes the case seed so it can be replayed deterministically:
//!
//! ```ignore
//! prop(100, |rng| {
//!     let n = rng.index(50) + 1;
//!     ...assertions...
//! });
//! ```

use crate::core::rng::Pcg64;

pub mod faults;

/// Run `cases` generated test cases. Each case gets a fresh, seeded RNG;
/// panics are caught and re-raised with the case seed attached.
pub fn prop<F: Fn(&mut Pcg64) + std::panic::RefUnwindSafe>(cases: u64, f: F) {
    let base = std::env::var("LGD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg64::seeded(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (replay with LGD_PROP_SEED={base} \
                 and case seed {seed}): {msg}"
            );
        }
    }
}

/// Generators for common test inputs.
pub mod gen {
    use crate::core::matrix::{normalize, Matrix};
    use crate::core::rng::{Pcg64, Rng};

    /// Vector of gaussians.
    pub fn vec_f32(rng: &mut Pcg64, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.gaussian() as f32).collect()
    }

    /// Unit-norm vector.
    pub fn unit_vec(rng: &mut Pcg64, len: usize) -> Vec<f32> {
        let mut v = vec_f32(rng, len);
        normalize(&mut v);
        v
    }

    /// Matrix of unit-norm rows.
    pub fn unit_matrix(rng: &mut Pcg64, rows: usize, cols: usize) -> Matrix {
        let mut m = Matrix::zeros(0, 0);
        for _ in 0..rows {
            m.push_row(&unit_vec(rng, cols)).unwrap();
        }
        m
    }

    /// Size in [lo, hi].
    pub fn size(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.index(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    #[test]
    fn prop_passes_on_tautology() {
        prop(50, |rng| {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn prop_reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            prop(10, |rng| {
                // fail when the first byte is even — will happen quickly
                assert!(rng.next_u64() % 2 == 1, "even!");
            });
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string>".into());
        assert!(msg.contains("replay with LGD_PROP_SEED="), "msg: {msg}");
    }

    #[test]
    fn generators_produce_valid_shapes() {
        prop(20, |rng| {
            let n = gen::size(rng, 1, 10);
            let d = gen::size(rng, 1, 6);
            let m = gen::unit_matrix(rng, n, d);
            assert_eq!(m.rows(), n);
            assert_eq!(m.cols(), d);
            for i in 0..n {
                let norm = crate::core::matrix::norm2(m.row(i));
                assert!((norm - 1.0).abs() < 1e-4);
            }
        });
    }
}
