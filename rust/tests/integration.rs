//! Cross-module integration tests: full pipeline → estimator → trainer,
//! property-based invariants via `testkit`, and PJRT runtime cross-checks
//! (the runtime tests skip with a message when `make artifacts` hasn't run).

use std::sync::Arc;

use lgd::config::spec::{Backend, EstimatorKind, RunConfig};
use lgd::coordinator::draw_engine::{run_session, DrawEngineConfig};
use lgd::coordinator::metrics::Metrics;
use lgd::coordinator::pipeline::{streaming_build, streaming_build_sharded, PipelineConfig};
use lgd::coordinator::trainer::{train, GradSource};
use lgd::core::rng::Rng;
use lgd::data::preprocess::{preprocess, PreprocessOptions};
use lgd::data::SynthSpec;
use lgd::estimator::lgd::{LgdEstimator, LgdOptions};
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator, WeightedDraw};
use lgd::lsh::srp::DenseSrp;
use lgd::lsh::tables::BucketRead;
use lgd::model::{LinReg, Model};
use lgd::optim::Schedule;
use lgd::runtime::{run_harness, ServingCore, ServingSession};
use lgd::testkit::{gen, prop};

fn artifacts_available() -> Option<std::path::PathBuf> {
    let dir = lgd::runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime test: no artifacts at {}", dir.display());
        None
    }
}

/// End-to-end: synthetic data → streaming pipeline build → LGD estimator →
/// manual training loop converges.
#[test]
fn pipeline_to_training_end_to_end() {
    let ds = SynthSpec::power_law("e2e", 1200, 16, 3).generate().unwrap();
    let hasher = DenseSrp::new(17, 4, 20, 5);
    let metrics = Metrics::new();
    let (pre, tables, report) =
        streaming_build(ds, hasher, &PipelineConfig::default(), &metrics).unwrap();
    assert_eq!(report.records, 1200);
    let mut est = LgdEstimator::from_parts(&pre, tables, 7, LgdOptions::default());
    let model = LinReg;
    let mut theta = vec![0.0f32; 16];
    let mut g = vec![0.0f32; 16];
    let loss0 = model.mean_loss(&pre.data, &theta);
    for _ in 0..3 * 1200 {
        let dr = est.draw(&theta);
        let (x, y) = pre.data.example(dr.index);
        model.grad(x, y, &theta, &mut g);
        let w = (dr.weight.min(5.0) * 0.05) as f32;
        lgd::core::matrix::axpy(-w, &g, &mut theta);
    }
    let loss1 = model.mean_loss(&pre.data, &theta);
    assert!(loss1 < loss0 * 0.8, "pipeline-fed LGD did not converge: {loss0} -> {loss1}");
}

/// Sharded engine end-to-end: config-driven training with `lsh.shards = 4`
/// selects the shard-mixture estimator, reports one build timing per shard,
/// and still converges.
#[test]
fn sharded_training_end_to_end() {
    let ds = SynthSpec::power_law("shard-e2e", 800, 12, 19).generate().unwrap();
    let (tr, te) = ds.split(0.9, 3).unwrap();
    let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
    let mut cfg = RunConfig::default();
    cfg.train.estimator = EstimatorKind::Lgd;
    cfg.train.epochs = 3;
    cfg.train.schedule = Schedule::Const(0.05);
    cfg.lsh.k = 4;
    cfg.lsh.l = 16;
    cfg.lsh.shards = 4;
    let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
    assert_eq!(out.estimator, "lgd-sharded");
    assert_eq!(out.shard_build_secs.len(), 4);
    let first = out.curve.first().unwrap().train_loss;
    let last = out.curve.last().unwrap().train_loss;
    assert!(last < first * 0.9, "sharded training did not descend: {first} -> {last}");
}

/// Sharded mirror of the pipeline's `streaming_matches_batch_path`: a
/// streaming sharded ingest must be draw-for-draw identical to the batch
/// `build_shard_tables` path under the same seed — single draws, batch
/// draws, and fallback counters.
#[test]
fn streaming_sharded_matches_batch_draw_for_draw() {
    let ds = SynthSpec::power_law("ss-e2e", 300, 10, 17).generate().unwrap();
    let hasher = DenseSrp::new(11, 4, 12, 19);
    let pre_b = preprocess(ds.clone(), &PreprocessOptions::default()).unwrap();
    let mut batch =
        ShardedLgdEstimator::new(&pre_b, hasher.clone(), 23, LgdOptions::default(), 4).unwrap();
    let metrics = Metrics::new();
    let (pre_s, shards, report) =
        streaming_build_sharded(ds, hasher, 4, true, &PipelineConfig::default(), &metrics)
            .unwrap();
    assert_eq!(report.records, 300);
    let mut stream = ShardedLgdEstimator::from_shards(&pre_s, shards, 23, LgdOptions::default());
    let theta: Vec<f32> = (0..10).map(|j| 0.03 * (j as f32 - 5.0)).collect();
    for i in 0..600 {
        let a = batch.draw(&theta);
        let b = stream.draw(&theta);
        assert_eq!(a, b, "draw {i} diverged between batch and streaming builds");
    }
    let (mut xa, mut xb) = (Vec::new(), Vec::new());
    for round in 0..4 {
        batch.draw_batch(&theta, 32, &mut xa);
        stream.draw_batch(&theta, 32, &mut xb);
        assert_eq!(xa, xb, "batch round {round} diverged");
    }
    assert_eq!(batch.stats().fallbacks, stream.stats().fallbacks);
}

/// The Theorem-1 regression for *live* shards: after a scripted
/// insert/remove/skew/rebalance sequence, ~50k seeded draws from the
/// sharded estimator must match the recomputed exact per-example mixture
/// probabilities. Conditional on the built tables and the query, shard `s`
/// is picked with probability `R_s/R` and Algorithm 1 inside it returns
/// local row `i` with probability `(1/#nonempty) Σ_t 1{i ∈ B_t}/|B_t|`
/// (the same enumeration `lsh::sampler` validates for one structure).
/// Migration bugs — stale prefix sums, dropped mirror rows, mis-keyed
/// buckets — all show up as frequency/probability mismatches here.
#[test]
fn mixture_probabilities_exact_under_mutation() {
    mixture_gate(false);
}

/// The same Theorem-1 gate against the **sealed** CSR-arena layout — the
/// one that actually serves draws by default — so exactness is enforced on
/// the arena + delta-overlay + compaction path, not just the Vec layout.
#[test]
fn mixture_probabilities_exact_under_mutation_sealed() {
    mixture_gate(true);
}

/// Exact per-example probabilities of the current mixture, conditional on
/// the built tables and the query from `theta`: shard `s` is picked with
/// probability `R_s/R` and Algorithm 1 inside it returns local row `i`
/// with probability `(1/#nonempty) Σ_t 1{i ∈ B_t}/|B_t|` (the same
/// enumeration `lsh::sampler` validates for one structure). Takes the
/// shard set directly so the estimator gates and the shared-read serving
/// gates enumerate through the identical code path.
fn exact_mixture_probs(
    pre: &lgd::data::preprocess::Preprocessed,
    set: &lgd::coordinator::pipeline::ShardSet<DenseSrp>,
    theta: &[f32],
) -> Vec<f64> {
    let n = pre.data.len();
    let mut q = Vec::new();
    pre.query(theta, &mut q);
    let r_total = set.total_rows() as f64;
    let mut p = vec![0.0f64; n];
    for s in 0..set.shard_count() {
        let st = set.shard(s);
        if st.rows.is_empty() {
            continue;
        }
        let l = st.tables.hasher().l();
        let nonempty = (0..l).filter(|&t| !st.tables.query_bucket(t, &q).is_empty()).count();
        assert!(nonempty > 0, "shard {s}: query hits no bucket — setup too sparse");
        let frac = st.stored.rows() as f64 / r_total;
        for t in 0..l {
            let b = st.tables.query_bucket(t, &q);
            if b.is_empty() {
                continue;
            }
            let w = frac / (nonempty as f64 * b.len() as f64);
            for local in b.iter() {
                let row = st.rows[local as usize] as usize;
                let ex = if row >= n { row - n } else { row };
                p[ex] += w;
            }
        }
    }
    let sum: f64 = p.iter().sum();
    assert!((sum - 1.0).abs() < 1e-9, "exact probabilities must sum to 1, got {sum}");
    p
}

/// The Theorem-1 statistical gate: total-variation and chi-square bounds
/// of `m` seeded draws (`counts`) against the enumerated exact
/// probabilities, plus a per-example relative check on well-populated
/// categories. Deterministic under fixed seeds.
fn assert_mixture_close(p: &[f64], counts: &[u64], m: usize) {
    let n = p.len();
    let mut tv = 0.0f64;
    let (mut chi2, mut cats) = (0.0f64, 0usize);
    for i in 0..n {
        let freq = counts[i] as f64 / m as f64;
        tv += (freq - p[i]).abs();
        let expect = p[i] * m as f64;
        if expect >= 5.0 {
            chi2 += (counts[i] as f64 - expect).powi(2) / expect;
            cats += 1;
        }
    }
    tv *= 0.5;
    assert!(tv < 0.035, "total variation {tv:.4} too large for {m} draws");
    let dof = cats.saturating_sub(1) as f64;
    assert!(
        chi2 < dof + 5.0 * (2.0 * dof).sqrt() + 10.0,
        "chi-square {chi2:.1} vs dof {dof}: mixture sampling is biased"
    );
    for i in 0..n {
        if p[i] > 0.005 {
            let freq = counts[i] as f64 / m as f64;
            let rel = (freq - p[i]).abs() / p[i];
            assert!(rel < 0.15, "example {i}: freq {freq:.5} vs exact {:.5}", p[i]);
        }
    }
}

fn mixture_gate(sealed: bool) {
    let n = 180usize;
    let ds = SynthSpec::power_law("mix", n, 8, 91).generate().unwrap();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    let hd = pre.hashed.cols();
    let opts = LgdOptions { sealed, ..LgdOptions::default() };
    let mut est =
        ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 12, 93), 95, opts, 3).unwrap();
    assert_eq!(est.shard_set().shard(0).tables.is_sealed(), sealed);
    // scripted stream: evict a block, re-admit some (least-loaded routing),
    // force a skewed burst into shard 0 under an auto-rebalance threshold,
    // then rebalance fully by hand
    for id in 0..60 {
        assert!(est.remove(id).unwrap());
    }
    for id in 0..20 {
        est.insert(id).unwrap();
    }
    est.set_rebalance_threshold(1.2);
    for id in 20..45 {
        est.shard_set_mut().insert_into(0, id, &pre.hashed).unwrap();
    }
    est.rebalance_to(1.0).unwrap();
    assert!(est.stats().migrations > 0, "the scripted skew must have migrated examples");

    // exact per-example probabilities of the mutated mixture
    let theta: Vec<f32> = (0..8).map(|j| 0.04 * (j as f32 - 3.0)).collect();
    let p = exact_mixture_probs(&pre, est.shard_set(), &theta);
    for id in 45..60 {
        assert_eq!(p[id], 0.0, "evicted example {id} still carries probability mass");
    }

    // ~50k seeded draws → empirical frequencies
    let m = 50_000usize;
    let mut counts = vec![0u64; n];
    for _ in 0..m {
        let d = est.draw(&theta);
        counts[d.index] += 1;
    }
    assert_eq!(est.stats().fallbacks, 0, "fallbacks would contaminate the distribution");
    for id in 45..60 {
        assert_eq!(counts[id], 0, "drew evicted example {id}");
    }
    assert_mixture_close(&p, &counts, m);
}

/// The Theorem-1 gate against the **async pipelined draw engine**
/// (per-shard sampler workers + mixer): the same scripted
/// insert/remove/skew/rebalance stream, then 50k draws served through
/// `run_session` must match the enumerated exact mixture probabilities —
/// and a second mutation burst *mid-stream* (between sessions: queue
/// flush + generation bump) must re-converge to the new exact
/// distribution with zero draws of dead rows.
#[test]
fn mixture_probabilities_exact_async() {
    let n = 180usize;
    let ds = SynthSpec::power_law("mix-async", n, 8, 91).generate().unwrap();
    let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
    let hd = pre.hashed.cols();
    let mut est =
        ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 12, 93), 95, LgdOptions::default(), 3)
            .unwrap();
    // the sync gate's scripted stream
    for id in 0..60 {
        assert!(est.remove(id).unwrap());
    }
    for id in 0..20 {
        est.insert(id).unwrap();
    }
    est.set_rebalance_threshold(1.2);
    for id in 20..45 {
        est.shard_set_mut().insert_into(0, id, &pre.hashed).unwrap();
    }
    est.rebalance_to(1.0).unwrap();
    let theta: Vec<f32> = (0..8).map(|j| 0.04 * (j as f32 - 3.0)).collect();
    let p = exact_mixture_probs(&pre, est.shard_set(), &theta);
    for id in 45..60 {
        assert_eq!(p[id], 0.0, "evicted example {id} still carries probability mass");
    }
    let (m, steps) = (100usize, 500usize); // 50k draws
    let engine = DrawEngineConfig { workers: 3, queue_depth: 256 };
    let mut counts = vec![0u64; n];
    let rep = run_session(&mut est, &engine, &theta, m, steps, |_, draws| {
        for d in draws {
            counts[d.index] += 1;
        }
        true
    })
    .unwrap();
    assert_eq!(rep.batches, steps);
    assert_eq!(rep.stale_drops, 0);
    assert_eq!(est.stats().fallbacks, 0, "fallbacks would contaminate the distribution");
    for id in 45..60 {
        assert_eq!(counts[id], 0, "async engine served evicted example {id}");
    }
    assert_mixture_close(&p, &counts, m * steps);

    // mid-stream mutation: a fresh burst between sessions — the next
    // session must serve the *new* exact mixture and never a dead row
    let g0 = est.shard_set().generation();
    for id in 60..90 {
        assert!(est.remove(id).unwrap());
    }
    for id in 45..60 {
        est.insert(id).unwrap();
    }
    est.rebalance_to(1.0).unwrap();
    assert!(est.shard_set().generation() > g0);
    let p2 = exact_mixture_probs(&pre, est.shard_set(), &theta);
    let mut counts2 = vec![0u64; n];
    let rep2 = run_session(&mut est, &engine, &theta, m, steps, |_, draws| {
        for d in draws {
            counts2[d.index] += 1;
        }
        true
    })
    .unwrap();
    assert_eq!(rep2.stale_drops, 0);
    assert_eq!(est.stats().fallbacks, 0);
    for id in 60..90 {
        assert_eq!(counts2[id], 0, "async engine served dead row {id} after mutation");
    }
    assert_mixture_close(&p2, &counts2, m * steps);
}

/// Property: every LGD draw returns a valid index, a probability in (0, 1]
/// and a positive weight, across random datasets and table shapes.
#[test]
fn prop_lgd_draws_always_valid() {
    prop(15, |rng| {
        let n = gen::size(rng, 30, 200);
        let d = gen::size(rng, 4, 12);
        let k = gen::size(rng, 2, 6);
        let l = gen::size(rng, 4, 16);
        let ds = SynthSpec::power_law("p", n, d, rng.next_u64()).generate().unwrap();
        let pre = preprocess(ds, &PreprocessOptions::default()).unwrap();
        let hasher = DenseSrp::new(pre.hashed.cols(), k, l, rng.next_u64());
        let mut est =
            LgdEstimator::new(&pre, hasher, rng.next_u64(), LgdOptions::default()).unwrap();
        let theta = gen::vec_f32(rng, d);
        for _ in 0..50 {
            let dr = est.draw(&theta);
            assert!(dr.index < n, "index {} out of {n}", dr.index);
            assert!(dr.prob > 0.0 && dr.prob <= 1.0, "prob {}", dr.prob);
            assert!(dr.weight > 0.0, "weight {}", dr.weight);
        }
    });
}

/// Property: the streaming pipeline preserves every record exactly once
/// for any worker count / channel capacity.
#[test]
fn prop_pipeline_preserves_records() {
    prop(10, |rng| {
        let n = gen::size(rng, 20, 150);
        let d = gen::size(rng, 3, 10);
        let workers = gen::size(rng, 1, 6);
        let cap = gen::size(rng, 1, 32);
        let ds = SynthSpec::power_law("p", n, d, rng.next_u64()).generate().unwrap();
        let hasher = DenseSrp::new(d + 1, 3, 8, rng.next_u64());
        let metrics = Metrics::new();
        let cfg = PipelineConfig { channel_cap: cap, hash_workers: workers };
        let (pre, tables, report) = streaming_build(ds, hasher, &cfg, &metrics).unwrap();
        assert_eq!(report.records, n);
        assert_eq!(pre.data.len(), n);
        assert_eq!(tables.len(), n);
        // every id in every table exactly once
        for t in 0..8 {
            let mut seen = vec![0u32; n];
            for code in 0..(1u32 << 3) {
                for &id in tables.bucket(t, code) {
                    seen[id as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "table {t} lost/duplicated ids");
        }
    });
}

/// Property: trainer runs to completion and produces monotone iteration
/// curves for random small configs.
#[test]
fn prop_trainer_curves_well_formed() {
    prop(8, |rng| {
        let n = gen::size(rng, 100, 400);
        let ds = SynthSpec::power_law("p", n, 8, rng.next_u64()).generate().unwrap();
        let (tr, te) = ds.split(0.8, rng.next_u64()).unwrap();
        let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
        let mut cfg = RunConfig::default();
        cfg.train.estimator = if rng.bernoulli(0.5) {
            EstimatorKind::Lgd
        } else {
            EstimatorKind::Sgd
        };
        cfg.train.epochs = 1 + rng.index(3);
        cfg.train.batch = 1 + rng.index(4);
        cfg.train.schedule = Schedule::Const(0.02);
        cfg.lsh.l = 10;
        let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
        assert!(!out.curve.is_empty());
        for w in out.curve.windows(2) {
            assert!(w[1].iter > w[0].iter);
            assert!(w[1].wall >= w[0].wall);
        }
        assert!(out.curve.iter().all(|p| p.train_loss.is_finite()));
    });
}

/// PJRT backend gradient agrees with the native model along a short
/// training run (three-layer integration).
#[test]
fn pjrt_trainer_matches_native_losses() {
    let Some(dir) = artifacts_available() else { return };
    let mut rt = lgd::runtime::Runtime::new(&dir).unwrap();
    let ds = SynthSpec::power_law("pjrt", 800, 90, 11).generate().unwrap();
    let (tr, te) = ds.split(0.9, 1).unwrap();
    let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
    let mut cfg = RunConfig::default();
    cfg.train.estimator = EstimatorKind::Sgd; // deterministic given seed
    cfg.train.epochs = 1;
    cfg.train.schedule = Schedule::Const(0.05);
    cfg.train.backend = Backend::Pjrt;
    cfg.lsh.l = 10;
    let out_pjrt = train(&cfg, &pre, &te, GradSource::Pjrt(&mut rt)).unwrap();
    cfg.train.backend = Backend::Native;
    let out_native = train(&cfg, &pre, &te, GradSource::Native).unwrap();
    // same estimator seed → same sample sequence → same final loss to f32
    // execution-order tolerance
    let a = out_pjrt.curve.last().unwrap().train_loss;
    let b = out_native.curve.last().unwrap().train_loss;
    assert!(
        (a - b).abs() / b.max(1e-9) < 1e-3,
        "pjrt {a} vs native {b} diverged"
    );
}

/// The simhash artifact reproduces the Rust DenseSrp bit layout — L1
/// kernel vs L3 substrate agreement. (The artifact takes the planes as an
/// argument, so we feed it the Rust family's planes.)
#[test]
fn simhash_artifact_matches_packing_contract() {
    let Some(dir) = artifacts_available() else { return };
    let mut rt = lgd::runtime::Runtime::new(&dir).unwrap();
    let entry = "simhash_b64_d91_k5_l100";
    if rt.manifest().entry(entry).is_err() {
        eprintln!("skipping: no {entry} artifact");
        return;
    }
    let (b, hd, k, l) = (64usize, 91usize, 5usize, 100usize);
    let mut rng = lgd::core::rng::Pcg64::seeded(3);
    let x: Vec<f32> = (0..b * hd).map(|_| rng.gaussian() as f32).collect();
    let planes: Vec<f32> = (0..k * l * hd).map(|_| rng.gaussian() as f32).collect();
    let args = [
        lgd::runtime::executor::lit_f32(&x, &[b, hd]).unwrap(),
        lgd::runtime::executor::lit_f32(&planes, &[k * l, hd]).unwrap(),
    ];
    let outs = rt.execute(entry, &args).unwrap();
    let codes = lgd::runtime::executor::to_vec_u32(&outs[0]).unwrap();
    assert_eq!(codes.len(), b * l);
    // reference packing in rust: bit (t*K + b) of row → MSB-first K-bit code
    for row in 0..4 {
        for t in 0..l {
            let mut want = 0u32;
            for bit in 0..k {
                let plane = &planes[(t * k + bit) * hd..(t * k + bit + 1) * hd];
                let xr = &x[row * hd..(row + 1) * hd];
                let dot: f64 = plane
                    .iter()
                    .zip(xr)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                want = (want << 1) | (dot >= 0.0) as u32;
            }
            assert_eq!(
                codes[row * l + t],
                want,
                "row {row} table {t}: artifact code mismatch"
            );
        }
    }
}

/// Warm-start acceptance (sync, batch = 1, AdaGrad): a run interrupted at
/// an epoch boundary and resumed from its snapshot is **identical** to the
/// uninterrupted run — same draws (RNG + query-cache window restored), same
/// θ/optimizer moments, so the loss curve matches bit for bit at every
/// shared iteration — and the warm start performs zero table-build work.
#[test]
fn snapshot_resume_matches_uninterrupted_training() {
    use lgd::config::spec::OptimizerKind;
    let ds = SynthSpec::power_law("resume", 300, 8, 77).generate().unwrap();
    let (tr, te) = ds.split(0.8, 3).unwrap();
    let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
    let mut cfg = RunConfig::default();
    cfg.train.estimator = EstimatorKind::Lgd;
    cfg.train.epochs = 4;
    cfg.train.optimizer = OptimizerKind::AdaGrad;
    cfg.train.schedule = Schedule::Const(0.05);
    cfg.lsh.k = 3;
    cfg.lsh.l = 10;
    cfg.lsh.shards = 2;
    let full = train(&cfg, &pre, &te, GradSource::Native).unwrap();

    let dir = std::env::temp_dir().join("lgd-int-resume");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sync.lgdsnap");
    let mut half_cfg = cfg.clone();
    half_cfg.train.epochs = 2;
    half_cfg.store.path = Some(path.clone());
    let half = train(&half_cfg, &pre, &te, GradSource::Native).unwrap();
    assert_eq!(half.autosaves, 1, "final save fires when a path is set");

    let mut resume_cfg = cfg.clone();
    resume_cfg.store.path = Some(path.clone());
    resume_cfg.store.resume = true;
    let snap = lgd::store::snapshot::load(&path).unwrap();
    let warm = lgd::coordinator::trainer::train_resumed(
        &resume_cfg,
        &te,
        GradSource::Native,
        snap,
    )
    .unwrap();
    assert!(warm.resumed);
    assert!(
        warm.shard_build_secs.iter().all(|&s| s == 0.0),
        "warm start must report zero table-build work"
    );
    assert_eq!(warm.iterations, full.iterations, "global iteration counter continues");
    // every shared curve iteration matches the uninterrupted run exactly
    for wp in &warm.curve {
        let fp = full
            .curve
            .iter()
            .find(|p| p.iter == wp.iter)
            .unwrap_or_else(|| panic!("uninterrupted run has no point at iter {}", wp.iter));
        assert_eq!(wp.train_loss, fp.train_loss, "iter {}: train loss diverged", wp.iter);
        assert_eq!(wp.test_loss, fp.test_loss, "iter {}: test loss diverged", wp.iter);
    }
    assert_eq!(warm.theta, full.theta, "final parameters diverged after resume");
    // the estimator's cumulative counters also continue exactly
    let (a, b) = (warm.est_stats, full.est_stats);
    assert_eq!(a.draws, b.draws);
    assert_eq!(a.fallbacks, b.fallbacks);
    assert_eq!(a.cost.randoms, b.cost.randoms);
    assert_eq!(a.cost.probes, b.cost.probes);
    assert_eq!(a.cost.codes, b.cost.codes, "resume must not re-hash anything extra");
    std::fs::remove_file(&path).unwrap();
}

/// The same warm-start identity through the async pipelined trainer
/// (per-shard sampler workers): sessions after a resume replay the
/// uninterrupted run's sessions draw for draw.
#[test]
fn snapshot_resume_matches_uninterrupted_training_async() {
    let ds = SynthSpec::power_law("resume-async", 300, 8, 79).generate().unwrap();
    let (tr, te) = ds.split(0.8, 5).unwrap();
    let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
    let mut cfg = RunConfig::default();
    cfg.train.estimator = EstimatorKind::Lgd;
    cfg.train.epochs = 4;
    cfg.train.schedule = Schedule::Const(0.05);
    cfg.train.batch = 8;
    cfg.lsh.k = 3;
    cfg.lsh.l = 10;
    cfg.lsh.shards = 2;
    cfg.lsh.async_workers = 2;
    let full = train(&cfg, &pre, &te, GradSource::Native).unwrap();
    assert_eq!(full.estimator, "lgd-async");

    let dir = std::env::temp_dir().join("lgd-int-resume");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("async.lgdsnap");
    let mut half_cfg = cfg.clone();
    half_cfg.train.epochs = 2;
    half_cfg.store.path = Some(path.clone());
    train(&half_cfg, &pre, &te, GradSource::Native).unwrap();

    let mut resume_cfg = cfg.clone();
    resume_cfg.store.path = Some(path.clone());
    resume_cfg.store.resume = true;
    let snap = lgd::store::snapshot::load(&path).unwrap();
    assert_eq!(snap.meta.shards, 2);
    let warm = lgd::coordinator::trainer::train_resumed(
        &resume_cfg,
        &te,
        GradSource::Native,
        snap,
    )
    .unwrap();
    assert_eq!(warm.estimator, "lgd-async");
    assert!(warm.resumed);
    assert!(warm.shard_build_secs.iter().all(|&s| s == 0.0));
    for wp in &warm.curve {
        let fp = full
            .curve
            .iter()
            .find(|p| p.iter == wp.iter)
            .unwrap_or_else(|| panic!("uninterrupted run has no point at iter {}", wp.iter));
        assert_eq!(wp.train_loss, fp.train_loss, "iter {}: async resume diverged", wp.iter);
    }
    assert_eq!(warm.theta, full.theta, "final parameters diverged after async resume");
    std::fs::remove_file(&path).unwrap();
}

/// Shared-read determinism: N concurrent pipelined sessions against one
/// `ServingCore` deliver exactly the draws of the same N sessions run one
/// after the other — for both bucket layouts ({Vec, sealed}) and shard
/// counts {1, 4}. Sessions share no mutable state, so thread interleaving
/// cannot change any per-seed stream.
#[test]
fn serving_concurrent_sessions_match_sequential() {
    for sealed in [false, true] {
        for shards in [1usize, 4] {
            let ds = SynthSpec::power_law("serve-det", 240, 10, 41).generate().unwrap();
            let pre = Arc::new(preprocess(ds, &PreprocessOptions::default()).unwrap());
            let hd = pre.hashed.cols();
            let opts = LgdOptions { sealed, ..LgdOptions::default() };
            let core =
                ServingCore::build(Arc::clone(&pre), DenseSrp::new(hd, 3, 12, 101), opts, shards)
                    .unwrap();
            let theta: Vec<f32> = (0..10).map(|j| 0.03 * (j as f32 - 5.0)).collect();
            let (clients, m, steps) = (4usize, 16usize, 6usize);
            let run = |core: &Arc<ServingCore<DenseSrp>>, c: usize| -> Vec<WeightedDraw> {
                let mut sess = ServingSession::open(core, 700 + c as u64);
                let mut got = Vec::new();
                let rep = sess
                    .run_pipelined(&theta, m, steps, 4 * m, |_, draws| {
                        got.extend_from_slice(draws);
                        true
                    })
                    .unwrap();
                assert_eq!(rep.batches, steps);
                assert_eq!(rep.stale_rejected, 0);
                got
            };
            let sequential: Vec<Vec<WeightedDraw>> =
                (0..clients).map(|c| run(&core, c)).collect();
            let concurrent: Vec<Vec<WeightedDraw>> = std::thread::scope(|scope| {
                let hs: Vec<_> = (0..clients)
                    .map(|c| {
                        let core = Arc::clone(&core);
                        let run = &run;
                        scope.spawn(move || run(&core, c))
                    })
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).collect()
            });
            assert_eq!(
                sequential, concurrent,
                "sealed={sealed} shards={shards}: concurrent sessions diverged from sequential"
            );
        }
    }
}

/// The Theorem-1 statistical gate on the **shared-read serving path**: the
/// sync gate's scripted insert/remove/skew/rebalance stream applied as
/// generation flips through `ServingCore::mutate`, then ~50k draws
/// aggregated across 8 concurrent live sessions must match the enumerated
/// exact mixture probabilities of the published generation — with zero
/// stale-generation serves and zero draws of dead rows. Then a flip under
/// pinned readers: the pinned session keeps serving its own (fully live)
/// generation while a fresh session sees only the new membership.
#[test]
fn mixture_probabilities_exact_serving_shared_read() {
    let n = 180usize;
    let ds = SynthSpec::power_law("mix-serve", n, 8, 91).generate().unwrap();
    let pre = Arc::new(preprocess(ds, &PreprocessOptions::default()).unwrap());
    let hd = pre.hashed.cols();
    let core = ServingCore::build(
        Arc::clone(&pre),
        DenseSrp::new(hd, 3, 12, 93),
        LgdOptions::default(),
        3,
    )
    .unwrap();
    // the sync gate's scripted stream, replayed as generation flips
    for id in 0..60 {
        assert!(core.remove(id).unwrap());
    }
    for id in 0..20 {
        core.insert(id).unwrap();
    }
    core.mutate(|set, pre| {
        for id in 20..45 {
            set.insert_into(0, id, &pre.hashed)?;
        }
        Ok(())
    })
    .unwrap();
    let migrated = core.rebalance_to(1.0).unwrap();
    assert!(migrated > 0, "the scripted skew must have migrated examples");
    assert_eq!(core.counters().flips, 60 + 20 + 2);

    let theta: Vec<f32> = (0..8).map(|j| 0.04 * (j as f32 - 3.0)).collect();
    let p = exact_mixture_probs(&pre, &core.pin(), &theta);
    for id in 45..60 {
        assert_eq!(p[id], 0.0, "evicted example {id} still carries probability mass");
    }

    // 8 live sessions × 25-draw batches × 250 steps = 50k draws
    let (clients, m, steps) = (8usize, 25usize, 250usize);
    let per_client: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let hs: Vec<_> = (0..clients)
            .map(|c| {
                let core = Arc::clone(&core);
                let theta = &theta;
                scope.spawn(move || {
                    let mut counts = vec![0u64; n];
                    let mut sess = ServingSession::open(&core, 95 + c as u64);
                    let rep = sess
                        .run_pipelined(theta, m, steps, 4 * m, |_, draws| {
                            for d in draws {
                                counts[d.index] += 1;
                            }
                            true
                        })
                        .unwrap();
                    assert_eq!(rep.batches, steps);
                    assert_eq!(rep.stale_rejected, 0);
                    assert_eq!(
                        sess.stats().fallbacks,
                        0,
                        "fallbacks would contaminate the distribution"
                    );
                    counts
                })
            })
            .collect();
        hs.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut counts = vec![0u64; n];
    for pc in &per_client {
        for (i, c) in pc.iter().enumerate() {
            counts[i] += c;
        }
    }
    for id in 45..60 {
        assert_eq!(counts[id], 0, "a live session served dead row {id}");
    }
    assert_mixture_close(&p, &counts, clients * m * steps);
    assert_eq!(core.counters().stale_rejected, 0, "zero stale-generation serves");

    // flip under pinned readers
    let mut pinned = ServingSession::open(&core, 4242);
    for id in 100..130 {
        assert!(core.remove(id).unwrap());
    }
    assert!(pinned.is_stale());
    let mut out = Vec::new();
    pinned.draw_batch(&theta, 64, &mut out); // every row is live *for its pin*
    assert_eq!(out.len(), 64);
    let p2 = exact_mixture_probs(&pre, &core.pin(), &theta);
    let mut fresh = ServingSession::open(&core, 4243);
    let mut counts2 = vec![0u64; n];
    for _ in 0..80 {
        fresh.draw_batch(&theta, 64, &mut out);
        for d in &out {
            counts2[d.index] += 1;
        }
    }
    for id in 100..130 {
        assert_eq!(p2[id], 0.0);
        assert_eq!(counts2[id], 0, "fresh session served row {id}, dead in its generation");
    }
    assert!(pinned.refresh());
    assert_eq!(pinned.generation(), core.generation());
}

/// Create/drop vs flip stress: six clients churn sessions (open → a few
/// batches → drop, refreshing mid-life) while a writer interleaves
/// insert/remove generation flips. Ids evicted before the churn starts and
/// never re-admitted must never be served by any session, whatever
/// generation it pinned; every aggregate counter adds up at the end.
#[test]
fn serving_session_churn_vs_generation_flips() {
    let n = 200usize;
    let ds = SynthSpec::power_law("serve-churn", n, 8, 83).generate().unwrap();
    let pre = Arc::new(preprocess(ds, &PreprocessOptions::default()).unwrap());
    let hd = pre.hashed.cols();
    let core = ServingCore::build(
        Arc::clone(&pre),
        DenseSrp::new(hd, 3, 12, 85),
        LgdOptions::default(),
        2,
    )
    .unwrap();
    // ids 170.. are dead in every generation the churn can observe
    for id in 170..n {
        assert!(core.remove(id).unwrap());
    }
    let base_flips = core.counters().flips;
    let theta: Vec<f32> = (0..8).map(|j| 0.04 * (j as f32 - 3.0)).collect();
    let writer_flips = 60u64;
    std::thread::scope(|scope| {
        let writer = {
            let core = Arc::clone(&core);
            scope.spawn(move || {
                // churn the low ids: every generation keeps 170.. dead
                for round in 0..writer_flips / 2 {
                    let id = (round % 30) as usize;
                    assert!(core.remove(id).unwrap());
                    core.insert(id).unwrap();
                }
            })
        };
        let clients: Vec<_> = (0..6u64)
            .map(|c| {
                let core = Arc::clone(&core);
                let theta = &theta;
                scope.spawn(move || {
                    let mut out = Vec::new();
                    for life in 0..20u64 {
                        let mut sess = ServingSession::open(&core, c * 1000 + life);
                        for batchno in 0..3 {
                            sess.draw_batch(theta, 32, &mut out);
                            assert_eq!(out.len(), 32);
                            for d in &out {
                                assert!(d.index < n);
                                assert!(
                                    d.index < 170,
                                    "served id {} — dead in every generation",
                                    d.index
                                );
                                assert!(d.weight > 0.0);
                            }
                            if batchno == 1 {
                                sess.refresh();
                            }
                        }
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for h in clients {
            h.join().unwrap();
        }
    });
    let counters = core.counters();
    assert_eq!(counters.flips, base_flips + writer_flips);
    assert_eq!(counters.sessions, 6 * 20);
    assert_eq!(counters.draws_served, 6 * 20 * 3 * 32);
    assert_eq!(counters.stale_rejected, 0);
    // the multi-client harness over the settled core still aggregates
    let rep = run_harness(&core, 8, 10, 32, &theta, 9000).unwrap();
    assert_eq!(rep.draws, 8 * 10 * 32);
    assert_eq!(rep.stale_rejected, 0);
    assert!(rep.draws_per_sec > 0.0);
}

/// CLI smoke: parse → train → CSV out, through the public binary surface.
#[test]
fn config_driven_training_run() {
    let dir = std::env::temp_dir().join("lgd-int-cli");
    std::fs::create_dir_all(&dir).unwrap();
    let toml = r#"
name = "int"
[data]
name = "pareto"
scale = 0.004
[train]
estimator = "lgd"
lr = 0.05
epochs = 2
"#;
    let doc = lgd::config::toml::TomlDoc::parse(toml).unwrap();
    let cfg = RunConfig::from_toml(&doc).unwrap();
    assert_eq!(cfg.name, "int");
    assert_eq!(cfg.train.epochs, 2);
    // run it
    let ds = SynthSpec::power_law("pareto", 200, 32, cfg.data.seed).generate().unwrap();
    let (tr, te) = ds.split(cfg.data.train_frac, cfg.data.seed).unwrap();
    let pre = preprocess(tr, &PreprocessOptions { center: cfg.lsh.center }).unwrap();
    let out = train(&cfg, &pre, &te, GradSource::Native).unwrap();
    assert!(out.curve.last().unwrap().train_loss.is_finite());
}
