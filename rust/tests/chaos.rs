//! Chaos suite: arms the *real* failpoint sites (`testkit::faults`) inside
//! production code and proves the fault-tolerance contracts hold — degraded
//! sessions serve the identical stream, crash-interrupted autosaves recover
//! the previous rotated generation, the retry client rides over wire
//! failures, and per-connection faults never take the supervised front
//! down.
//!
//! This binary only builds with `--features failpoints` (CI's chaos step);
//! without the feature it is empty. Real sites are armed **only** here:
//! the registry is process-global, so every test serializes on one gate
//! mutex and restores a clean slate through a drop guard, even on panic.
//! (Injected worker faults are panics by design — the "thread panicked"
//! noise on stderr is the fault being injected, not a test failure.)

#![cfg(feature = "failpoints")]

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread;
use std::time::Duration;

use lgd::coordinator::draw_engine::{run_session, DrawEngineConfig};
use lgd::data::preprocess::{preprocess, PreprocessOptions, Preprocessed};
use lgd::data::SynthSpec;
use lgd::estimator::lgd::LgdOptions;
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator, WeightedDraw};
use lgd::lsh::srp::DenseSrp;
use lgd::runtime::{
    serve_supervised, ClientOptions, RetryClient, RetryPolicy, ServeClient, ServeOptions,
    ServingCore, ServingSession,
};
use lgd::store::snapshot::{load, recover, rotated_path, save_rotated, LoadedSnapshot};
use lgd::testkit::faults::{self, Mode};

/// One test at a time: the failpoint registry is process-global.
static GATE: Mutex<()> = Mutex::new(());

fn serialize() -> MutexGuard<'static, ()> {
    // A failed sibling test poisons nothing structurally — take the gate
    // anyway, same policy as the registry itself.
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Restores the clean slate when dropped, even if the test panics while a
/// real site is still armed.
struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        faults::disarm_all();
    }
}

fn setup(n: usize, d: usize, seed: u64) -> Arc<Preprocessed> {
    let ds = SynthSpec::power_law("chaos", n, d, seed).generate().unwrap();
    Arc::new(preprocess(ds, &PreprocessOptions::default()).unwrap())
}

fn mk_core(pre: &Arc<Preprocessed>, shards: usize) -> Arc<ServingCore<DenseSrp>> {
    let hd = pre.hashed.cols();
    ServingCore::build(Arc::clone(pre), DenseSrp::new(hd, 3, 10, 61), LgdOptions::default(), shards)
        .unwrap()
}

/// Drift guard: the chaos suite below exercises exactly the registered
/// catalog — a new site added to production code must show up here (and
/// get a scenario) or this fails.
#[test]
fn chaos_site_catalog_matches_the_wired_sites() {
    assert_eq!(
        faults::SITES,
        &[
            faults::SNAPSHOT_WRITE,
            faults::SNAPSHOT_FSYNC,
            faults::SNAPSHOT_RENAME,
            faults::QUEUE_PUSH,
            faults::QUEUE_POP,
            faults::WORKER_START,
            faults::GENERATION_FLIP,
            faults::TCP_READ,
            faults::TCP_WRITE,
        ]
    );
}

/// The crash-recovery gate: a crash injected at every stage of the atomic
/// snapshot write (mid-write, pre-fsync, pre-rename) fails the save, and
/// `recover` falls back to the previous rotated generation — whose
/// restored engine serves a stream draw-for-draw identical to one restored
/// from the pristine file before the crash.
#[test]
fn chaos_crash_mid_autosave_recovers_previous_and_resumes_identical() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(90, 7, 131);
    let hd = pre.hashed.cols();
    let est =
        ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 8, 137), 139, LgdOptions::default(), 2)
            .unwrap();
    let dir = std::env::temp_dir().join("lgd-chaos-rotate");
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("auto.lgdsnap");
    let theta = vec![0.02f32; 7];

    for site in [faults::SNAPSHOT_WRITE, faults::SNAPSHOT_FSYNC, faults::SNAPSHOT_RENAME] {
        for slot in 0..3 {
            let p = rotated_path(&base, slot);
            if p.exists() {
                std::fs::remove_file(&p).unwrap();
            }
        }
        // two healthy rotated generations (identical state: save borrows)
        save_rotated(&base, 2, &est, None).unwrap();
        save_rotated(&base, 2, &est, None).unwrap();
        // the stream a restart would serve from the pristine newest file
        let LoadedSnapshot { pre: lpre, hasher, engine, .. } = load(&base).unwrap();
        let mut reference = lgd::store::snapshot::restore_boxed(hasher, &lpre, engine).unwrap();
        let mut want = Vec::new();
        let mut buf: Vec<WeightedDraw> = Vec::new();
        for _ in 0..3 {
            reference.draw_batch(&theta, 16, &mut buf);
            want.extend_from_slice(&buf);
        }
        // crash mid-autosave: rotation already shifted the previous
        // generation to slot 1; the new base never materializes
        faults::arm(site, Mode::Once);
        let err = save_rotated(&base, 2, &est, None);
        assert!(err.is_err(), "{site}: injected crash must fail the save");
        assert_eq!(faults::fires(site), 1, "{site}: the site must actually fire");
        let rec = recover(&base, 2).unwrap();
        assert_eq!(rec.slot, 1, "{site}: recovery must fall back to the rotated slot");
        assert_eq!(rec.skipped, 1, "{site}: the dead newest slot is skipped");
        let LoadedSnapshot { pre: rpre, hasher, engine, .. } = rec.snap;
        let mut revived = lgd::store::snapshot::restore_boxed(hasher, &rpre, engine).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            revived.draw_batch(&theta, 16, &mut buf);
            got.extend_from_slice(&buf);
        }
        assert_eq!(want, got, "{site}: recovered stream diverged from the pristine one");
    }
    for slot in 0..3 {
        let _ = std::fs::remove_file(rotated_path(&base, slot));
    }
}

/// The degraded-mode gate: a sampler thread killed at session start AND one
/// killed mid-stream (third queue push) both flip the session to the
/// synchronous fallback — the delivered stream, the handed-back RNG
/// position, and the draw counts stay identical to an undegraded run, and
/// the core counts each event without anything else stopping.
#[test]
fn chaos_degraded_session_serves_identical_stream() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(150, 8, 141);
    let core = mk_core(&pre, 2);
    let theta = vec![0.04f32; 8];
    let (m, steps) = (16usize, 6usize);

    // undegraded reference: the pipelined stream plus its continuation
    let mut reference = ServingSession::open(&core, 42);
    let mut want = Vec::new();
    reference
        .run_pipelined(&theta, m, steps, 64, |_, draws| {
            want.extend_from_slice(draws);
            true
        })
        .unwrap();
    let mut want_cont = Vec::new();
    reference.draw_batch(&theta, m, &mut want_cont);

    let faulted = [
        // the producer dies before assembling anything
        (faults::WORKER_START, Mode::Once, true),
        // the producer dies mid-stream, on its third push
        (faults::QUEUE_PUSH, Mode::Nth(3), false),
    ];
    for (round, (site, mode, filtered)) in faulted.into_iter().enumerate() {
        let mut sess = ServingSession::open(&core, 42);
        if filtered {
            faults::arm_at(site, mode, 0);
        } else {
            faults::arm(site, mode);
        }
        let mut got = Vec::new();
        let rep = sess
            .run_pipelined(&theta, m, steps, 64, |_, draws| {
                got.extend_from_slice(draws);
                true
            })
            .unwrap();
        assert_eq!(faults::fires(site), 1, "{site}: the site must actually fire");
        assert!(rep.degraded, "{site}: a dead sampler must degrade the session");
        assert_eq!(rep.batches, steps, "{site}: every batch still reaches the consumer");
        assert_eq!(rep.draws, (m * steps) as u64);
        assert_eq!(want, got, "{site}: degraded stream diverged from the healthy one");
        // RNG continuation: sync draws after the degraded run match too
        let mut cont = Vec::new();
        sess.draw_batch(&theta, m, &mut cont);
        assert_eq!(want_cont, cont, "{site}: post-degradation stream diverged");
        assert_eq!(
            core.counters().degraded_sessions,
            (round + 1) as u64,
            "{site}: each degradation is counted exactly once"
        );
        faults::disarm_all();
    }
}

/// An injected early-`None` from `DrawQueue::pop` looks like a dead queue
/// to the consumer: the session ends early but cleanly (no degradation —
/// the producer is healthy) and the session keeps serving afterwards.
#[test]
fn chaos_queue_pop_fault_ends_session_early_not_fatally() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(120, 8, 151);
    let core = mk_core(&pre, 2);
    let theta = vec![0.03f32; 8];
    let mut sess = ServingSession::open(&core, 9);
    faults::arm(faults::QUEUE_POP, Mode::Once);
    let rep = sess.run_pipelined(&theta, 16, 5, 64, |_, _| true).unwrap();
    assert_eq!(faults::fires(faults::QUEUE_POP), 1);
    assert_eq!(rep.batches, 0, "the consumer saw a dead queue on its first pop");
    assert!(!rep.degraded, "a healthy producer is not a degraded session");
    let mut out = Vec::new();
    sess.draw_batch(&theta, 16, &mut out);
    assert_eq!(out.len(), 16, "the session must keep serving after the early end");
}

/// A shard worker killed at start (poisoning its candidate queue for real)
/// fails `run_session` with a clean pipeline error — and the estimator's
/// synchronous path, plus a fresh disarmed session, keep working.
#[test]
fn chaos_killed_shard_worker_fails_session_cleanly_and_sync_survives() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(160, 8, 161);
    let hd = pre.hashed.cols();
    let mut est =
        ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 10, 163), 7, LgdOptions::default(), 3)
            .unwrap();
    let theta = vec![0.05f32; 8];
    let cfg = DrawEngineConfig { workers: 3, queue_depth: 128 };

    faults::arm_at(faults::WORKER_START, Mode::Once, 1);
    let err = run_session(&mut est, &cfg, &theta, 10, 5, |_, _| true).unwrap_err();
    assert!(
        err.to_string().contains("shard worker"),
        "want a clean shard-worker error, got: {err}"
    );
    assert_eq!(faults::fires(faults::WORKER_START), 1);

    // the engine survives: synchronous draws and a fresh session both work
    let mut out = Vec::new();
    est.draw_batch(&theta, 10, &mut out);
    assert_eq!(out.len(), 10);
    let rep = run_session(&mut est, &cfg, &theta, 10, 5, |_, _| true).unwrap();
    assert_eq!(rep.batches, 5, "a disarmed rerun must complete normally");
}

/// A generation flip that fails (after taking the writer lock, before
/// publishing) is fully isolated: nothing is published, the flip counter
/// does not move, pinned sessions keep serving, and the next flip works.
#[test]
fn chaos_generation_flip_failure_is_isolated() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(100, 6, 171);
    let core = mk_core(&pre, 2);
    let theta = vec![0.02f32; 6];
    let mut sess = ServingSession::open(&core, 3);
    let g0 = core.generation();

    faults::arm(faults::GENERATION_FLIP, Mode::Once);
    assert!(core.remove(0).is_err(), "the armed flip must fail");
    assert_eq!(faults::fires(faults::GENERATION_FLIP), 1);
    assert_eq!(core.generation(), g0, "a failed flip publishes nothing");
    assert_eq!(core.counters().flips, 0);

    let mut out = Vec::new();
    sess.draw_batch(&theta, 12, &mut out);
    assert_eq!(out.len(), 12, "sessions keep serving through a failed flip");
    assert!(core.remove(0).unwrap(), "the next (disarmed) flip succeeds");
    assert!(core.generation() > g0);
    assert_eq!(core.counters().flips, 1);
}

/// The reconnect gate: a read failure injected into the client mid-run
/// makes [`RetryClient`] back off, reconnect with the same seed, and
/// fast-forward — the assembled stream is draw-for-draw what an
/// uninterrupted client (and an in-process session) would have seen, and
/// the server keeps serving.
#[test]
fn chaos_retry_client_resumes_identical_stream() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let d = 6usize;
    let pre = setup(110, d, 181);
    let core = mk_core(&pre, 2);
    let theta = vec![0.05f32; d];
    let (m, steps) = (12usize, 4usize);

    // uninterrupted reference: in-process session, same seed
    let mut reference = ServingSession::open(&core, 77);
    let mut want = Vec::new();
    let mut buf = Vec::new();
    for _ in 0..steps {
        reference.draw_batch(&theta, m, &mut buf);
        want.extend_from_slice(&buf);
    }

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    let opts = ServeOptions::default();
    thread::scope(|scope| {
        let corer = &core;
        let stopr = &stop;
        let optsr = &opts;
        let server = scope.spawn(move || serve_supervised(corer, listener, stopr, optsr));

        let policy = RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(8),
        };
        let mut client =
            RetryClient::connect(&addr.to_string(), 77, ClientOptions::default(), policy).unwrap();
        let mut got = Vec::new();
        for step in 0..steps {
            if step == 2 {
                // the next client-side frame read dies mid-run
                faults::arm_at(faults::TCP_READ, Mode::Once, faults::SIDE_CLIENT);
            }
            let (_, draws) = client.draw(&theta, m).unwrap();
            got.extend_from_slice(&draws);
        }
        assert_eq!(faults::fires(faults::TCP_READ), 1, "the injected read failure fired");
        assert_eq!(client.retries(), 1, "exactly one reconnect");
        assert_eq!(want, got, "resumed stream diverged from the uninterrupted one");
        client.bye().unwrap();

        // the server is untouched: a fresh client draws, and STATS shows a
        // healthy front
        let mut fresh = ServeClient::connect(addr, 99).unwrap();
        let (_, extra) = fresh.draw(&theta, 5).unwrap();
        assert_eq!(extra.len(), 5);
        let stats = fresh.stats().unwrap();
        assert_eq!(stats.degraded_sessions, 0);
        fresh.bye().unwrap();

        stop.store(true, Ordering::Relaxed);
        let totals = server.join().unwrap().unwrap();
        // conn 2 (2 fast-forward replays + the retried step + step 3) and
        // conn 3 (the 5-draw health check) always land. Conn 1 adds its 3
        // served batches unless its handler lost the race writing the
        // reply the client never reads against the dropped connection —
        // in which case that handler's draws are not totalled and the
        // broken pipe counts as the (benign) connection error.
        let conn2_and_3 = (4 * m + 5) as u64;
        assert!(
            totals.draws == conn2_and_3 + (3 * m) as u64 || totals.draws == conn2_and_3,
            "unexpected draw total {}",
            totals.draws
        );
        assert_eq!(totals.connections, 3);
        assert!(totals.conn_errors <= 1, "only conn 1's benign write race may error");
        assert_eq!(totals.rejected_at_capacity, 0);
    });
}

/// Wire faults on the server's read path and the write path are isolated
/// to their connection: the victim client errors, the fault is counted,
/// and the next client is served normally — the front never exits.
#[test]
fn chaos_tcp_faults_are_counted_not_fatal() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let d = 6usize;
    let pre = setup(100, d, 191);
    let core = mk_core(&pre, 2);
    let theta = vec![0.05f32; d];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    let opts = ServeOptions::default();
    thread::scope(|scope| {
        let corer = &core;
        let stopr = &stop;
        let optsr = &opts;
        let server = scope.spawn(move || serve_supervised(corer, listener, stopr, optsr));

        // server-side read failure: the handler errors, the HELLO never
        // answers, and the failure lands in conn_errors — not in Err
        faults::arm_at(faults::TCP_READ, Mode::Once, faults::SIDE_SERVER);
        assert!(ServeClient::connect(addr, 1).is_err());
        assert_eq!(faults::fires(faults::TCP_READ), 1);

        // client-side write failure: the HELLO frame never leaves the
        // process; the server just sees a connection that goes away
        faults::arm(faults::TCP_WRITE, Mode::Once);
        assert!(ServeClient::connect(addr, 2).is_err());
        assert_eq!(faults::fires(faults::TCP_WRITE), 1);

        // the front is unaffected
        let mut ok = ServeClient::connect(addr, 3).unwrap();
        let (_, draws) = ok.draw(&theta, 9).unwrap();
        assert_eq!(draws.len(), 9);
        ok.bye().unwrap();

        stop.store(true, Ordering::Relaxed);
        let totals = server.join().unwrap().unwrap();
        assert_eq!(totals.draws, 9);
        assert_eq!(totals.connections, 3);
        assert_eq!(totals.conn_errors, 1, "exactly the injected server-side read failure");
        assert_eq!(totals.rejected_at_capacity, 0);
    });
}

/// The determinism gate for the compiled-in registry: with failpoints
/// compiled in (this whole binary) but disarmed, pipelined serving still
/// replays the synchronous stream bit-for-bit and nothing degrades.
#[test]
fn chaos_disarmed_failpoints_leave_streams_identical() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(140, 8, 201);
    let core = mk_core(&pre, 3);
    let theta = vec![0.03f32; 8];
    let (m, steps) = (20usize, 5usize);
    let mut sync = ServingSession::open(&core, 17);
    let mut piped = ServingSession::open(&core, 17);
    let mut want = Vec::new();
    let mut buf = Vec::new();
    for _ in 0..steps {
        sync.draw_batch(&theta, m, &mut buf);
        want.extend_from_slice(&buf);
    }
    let mut got = Vec::new();
    let rep = piped
        .run_pipelined(&theta, m, steps, 64, |_, draws| {
            got.extend_from_slice(draws);
            true
        })
        .unwrap();
    assert!(!rep.degraded);
    assert_eq!(want, got, "disarmed failpoints changed a stream");
    assert_eq!(core.counters().degraded_sessions, 0);
}
