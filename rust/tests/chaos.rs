//! Chaos suite: arms the *real* failpoint sites (`testkit::faults`) inside
//! production code and proves the fault-tolerance contracts hold — degraded
//! sessions serve the identical stream, crash-interrupted autosaves recover
//! the previous rotated generation, the retry client rides over wire
//! failures, and per-connection faults never take the supervised front
//! down.
//!
//! This binary only builds with `--features failpoints` (CI's chaos step);
//! without the feature it is empty. Real sites are armed **only** here:
//! the registry is process-global, so every test serializes on one gate
//! mutex and restores a clean slate through a drop guard, even on panic.
//! (Injected worker faults are panics by design — the "thread panicked"
//! noise on stderr is the fault being injected, not a test failure.)

#![cfg(feature = "failpoints")]

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread;
use std::time::Duration;

use lgd::config::spec::{EstimatorKind, RunConfig};
use lgd::coordinator::draw_engine::{run_session, DrawEngineConfig};
use lgd::coordinator::trainer::{train, train_resumed, GradSource, TrainOutcome};
use lgd::core::error::Error;
use lgd::data::preprocess::{preprocess, PreprocessOptions, Preprocessed};
use lgd::data::{Dataset, SynthSpec};
use lgd::estimator::lgd::LgdOptions;
use lgd::estimator::{GradientEstimator, ShardedLgdEstimator, WeightedDraw};
use lgd::lsh::srp::DenseSrp;
use lgd::optim::Schedule;
use lgd::runtime::{
    serve_supervised, ClientOptions, RetryClient, RetryPolicy, ServeClient, ServeOptions,
    ServingCore, ServingSession,
};
use lgd::store::snapshot::{load, recover, rotated_path, save_rotated, LoadedSnapshot};
use lgd::testkit::faults::{self, Mode};

/// One test at a time: the failpoint registry is process-global.
static GATE: Mutex<()> = Mutex::new(());

fn serialize() -> MutexGuard<'static, ()> {
    // A failed sibling test poisons nothing structurally — take the gate
    // anyway, same policy as the registry itself.
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Restores the clean slate when dropped, even if the test panics while a
/// real site is still armed.
struct Disarm;

impl Drop for Disarm {
    fn drop(&mut self) {
        faults::disarm_all();
    }
}

fn setup(n: usize, d: usize, seed: u64) -> Arc<Preprocessed> {
    let ds = SynthSpec::power_law("chaos", n, d, seed).generate().unwrap();
    Arc::new(preprocess(ds, &PreprocessOptions::default()).unwrap())
}

fn mk_core(pre: &Arc<Preprocessed>, shards: usize) -> Arc<ServingCore<DenseSrp>> {
    let hd = pre.hashed.cols();
    ServingCore::build(Arc::clone(pre), DenseSrp::new(hd, 3, 10, 61), LgdOptions::default(), shards)
        .unwrap()
}

/// Drift guard: the chaos suite below exercises exactly the registered
/// catalog — a new site added to production code must show up here (and
/// get a scenario) or this fails.
#[test]
fn chaos_site_catalog_matches_the_wired_sites() {
    assert_eq!(
        faults::SITES,
        &[
            faults::SNAPSHOT_WRITE,
            faults::SNAPSHOT_FSYNC,
            faults::SNAPSHOT_RENAME,
            faults::QUEUE_PUSH,
            faults::QUEUE_POP,
            faults::WORKER_START,
            faults::GENERATION_FLIP,
            faults::TCP_READ,
            faults::TCP_WRITE,
            faults::GRAD_NAN,
            faults::THETA_POISON,
            faults::LOSS_CORRUPT,
        ]
    );
}

/// The crash-recovery gate: a crash injected at every stage of the atomic
/// snapshot write (mid-write, pre-fsync, pre-rename) fails the save, and
/// `recover` falls back to the previous rotated generation — whose
/// restored engine serves a stream draw-for-draw identical to one restored
/// from the pristine file before the crash.
#[test]
fn chaos_crash_mid_autosave_recovers_previous_and_resumes_identical() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(90, 7, 131);
    let hd = pre.hashed.cols();
    let est =
        ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 8, 137), 139, LgdOptions::default(), 2)
            .unwrap();
    let dir = std::env::temp_dir().join("lgd-chaos-rotate");
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("auto.lgdsnap");
    let theta = vec![0.02f32; 7];

    for site in [faults::SNAPSHOT_WRITE, faults::SNAPSHOT_FSYNC, faults::SNAPSHOT_RENAME] {
        for slot in 0..3 {
            let p = rotated_path(&base, slot);
            if p.exists() {
                std::fs::remove_file(&p).unwrap();
            }
        }
        // two healthy rotated generations (identical state: save borrows)
        save_rotated(&base, 2, &est, None).unwrap();
        save_rotated(&base, 2, &est, None).unwrap();
        // the stream a restart would serve from the pristine newest file
        let LoadedSnapshot { pre: lpre, hasher, engine, .. } = load(&base).unwrap();
        let mut reference = lgd::store::snapshot::restore_boxed(hasher, &lpre, engine).unwrap();
        let mut want = Vec::new();
        let mut buf: Vec<WeightedDraw> = Vec::new();
        for _ in 0..3 {
            reference.draw_batch(&theta, 16, &mut buf);
            want.extend_from_slice(&buf);
        }
        // crash mid-autosave: rotation already shifted the previous
        // generation to slot 1; the new base never materializes
        faults::arm(site, Mode::Once);
        let err = save_rotated(&base, 2, &est, None);
        assert!(err.is_err(), "{site}: injected crash must fail the save");
        assert_eq!(faults::fires(site), 1, "{site}: the site must actually fire");
        let rec = recover(&base, 2).unwrap();
        assert_eq!(rec.slot, 1, "{site}: recovery must fall back to the rotated slot");
        assert_eq!(rec.skipped, 1, "{site}: the dead newest slot is skipped");
        let LoadedSnapshot { pre: rpre, hasher, engine, .. } = rec.snap;
        let mut revived = lgd::store::snapshot::restore_boxed(hasher, &rpre, engine).unwrap();
        let mut got = Vec::new();
        for _ in 0..3 {
            revived.draw_batch(&theta, 16, &mut buf);
            got.extend_from_slice(&buf);
        }
        assert_eq!(want, got, "{site}: recovered stream diverged from the pristine one");
    }
    for slot in 0..3 {
        let _ = std::fs::remove_file(rotated_path(&base, slot));
    }
}

/// The degraded-mode gate: a sampler thread killed at session start AND one
/// killed mid-stream (third queue push) both flip the session to the
/// synchronous fallback — the delivered stream, the handed-back RNG
/// position, and the draw counts stay identical to an undegraded run, and
/// the core counts each event without anything else stopping.
#[test]
fn chaos_degraded_session_serves_identical_stream() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(150, 8, 141);
    let core = mk_core(&pre, 2);
    let theta = vec![0.04f32; 8];
    let (m, steps) = (16usize, 6usize);

    // undegraded reference: the pipelined stream plus its continuation
    let mut reference = ServingSession::open(&core, 42);
    let mut want = Vec::new();
    reference
        .run_pipelined(&theta, m, steps, 64, |_, draws| {
            want.extend_from_slice(draws);
            true
        })
        .unwrap();
    let mut want_cont = Vec::new();
    reference.draw_batch(&theta, m, &mut want_cont);

    let faulted = [
        // the producer dies before assembling anything
        (faults::WORKER_START, Mode::Once, true),
        // the producer dies mid-stream, on its third push
        (faults::QUEUE_PUSH, Mode::Nth(3), false),
    ];
    for (round, (site, mode, filtered)) in faulted.into_iter().enumerate() {
        let mut sess = ServingSession::open(&core, 42);
        if filtered {
            faults::arm_at(site, mode, 0);
        } else {
            faults::arm(site, mode);
        }
        let mut got = Vec::new();
        let rep = sess
            .run_pipelined(&theta, m, steps, 64, |_, draws| {
                got.extend_from_slice(draws);
                true
            })
            .unwrap();
        assert_eq!(faults::fires(site), 1, "{site}: the site must actually fire");
        assert!(rep.degraded, "{site}: a dead sampler must degrade the session");
        assert_eq!(rep.batches, steps, "{site}: every batch still reaches the consumer");
        assert_eq!(rep.draws, (m * steps) as u64);
        assert_eq!(want, got, "{site}: degraded stream diverged from the healthy one");
        // RNG continuation: sync draws after the degraded run match too
        let mut cont = Vec::new();
        sess.draw_batch(&theta, m, &mut cont);
        assert_eq!(want_cont, cont, "{site}: post-degradation stream diverged");
        assert_eq!(
            core.counters().degraded_sessions,
            (round + 1) as u64,
            "{site}: each degradation is counted exactly once"
        );
        faults::disarm_all();
    }
}

/// An injected early-`None` from `DrawQueue::pop` looks like a dead queue
/// to the consumer: the session ends early but cleanly (no degradation —
/// the producer is healthy) and the session keeps serving afterwards.
#[test]
fn chaos_queue_pop_fault_ends_session_early_not_fatally() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(120, 8, 151);
    let core = mk_core(&pre, 2);
    let theta = vec![0.03f32; 8];
    let mut sess = ServingSession::open(&core, 9);
    faults::arm(faults::QUEUE_POP, Mode::Once);
    let rep = sess.run_pipelined(&theta, 16, 5, 64, |_, _| true).unwrap();
    assert_eq!(faults::fires(faults::QUEUE_POP), 1);
    assert_eq!(rep.batches, 0, "the consumer saw a dead queue on its first pop");
    assert!(!rep.degraded, "a healthy producer is not a degraded session");
    let mut out = Vec::new();
    sess.draw_batch(&theta, 16, &mut out);
    assert_eq!(out.len(), 16, "the session must keep serving after the early end");
}

/// A shard worker killed at start (poisoning its candidate queue for real)
/// fails `run_session` with a clean pipeline error — and the estimator's
/// synchronous path, plus a fresh disarmed session, keep working.
#[test]
fn chaos_killed_shard_worker_fails_session_cleanly_and_sync_survives() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(160, 8, 161);
    let hd = pre.hashed.cols();
    let mut est =
        ShardedLgdEstimator::new(&pre, DenseSrp::new(hd, 3, 10, 163), 7, LgdOptions::default(), 3)
            .unwrap();
    let theta = vec![0.05f32; 8];
    let cfg = DrawEngineConfig { workers: 3, queue_depth: 128 };

    faults::arm_at(faults::WORKER_START, Mode::Once, 1);
    let err = run_session(&mut est, &cfg, &theta, 10, 5, |_, _| true).unwrap_err();
    assert!(
        err.to_string().contains("shard worker"),
        "want a clean shard-worker error, got: {err}"
    );
    assert_eq!(faults::fires(faults::WORKER_START), 1);

    // the engine survives: synchronous draws and a fresh session both work
    let mut out = Vec::new();
    est.draw_batch(&theta, 10, &mut out);
    assert_eq!(out.len(), 10);
    let rep = run_session(&mut est, &cfg, &theta, 10, 5, |_, _| true).unwrap();
    assert_eq!(rep.batches, 5, "a disarmed rerun must complete normally");
}

/// A generation flip that fails (after taking the writer lock, before
/// publishing) is fully isolated: nothing is published, the flip counter
/// does not move, pinned sessions keep serving, and the next flip works.
#[test]
fn chaos_generation_flip_failure_is_isolated() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(100, 6, 171);
    let core = mk_core(&pre, 2);
    let theta = vec![0.02f32; 6];
    let mut sess = ServingSession::open(&core, 3);
    let g0 = core.generation();

    faults::arm(faults::GENERATION_FLIP, Mode::Once);
    assert!(core.remove(0).is_err(), "the armed flip must fail");
    assert_eq!(faults::fires(faults::GENERATION_FLIP), 1);
    assert_eq!(core.generation(), g0, "a failed flip publishes nothing");
    assert_eq!(core.counters().flips, 0);

    let mut out = Vec::new();
    sess.draw_batch(&theta, 12, &mut out);
    assert_eq!(out.len(), 12, "sessions keep serving through a failed flip");
    assert!(core.remove(0).unwrap(), "the next (disarmed) flip succeeds");
    assert!(core.generation() > g0);
    assert_eq!(core.counters().flips, 1);
}

/// The reconnect gate: a read failure injected into the client mid-run
/// makes [`RetryClient`] back off, reconnect with the same seed, and
/// fast-forward — the assembled stream is draw-for-draw what an
/// uninterrupted client (and an in-process session) would have seen, and
/// the server keeps serving.
#[test]
fn chaos_retry_client_resumes_identical_stream() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let d = 6usize;
    let pre = setup(110, d, 181);
    let core = mk_core(&pre, 2);
    let theta = vec![0.05f32; d];
    let (m, steps) = (12usize, 4usize);

    // uninterrupted reference: in-process session, same seed
    let mut reference = ServingSession::open(&core, 77);
    let mut want = Vec::new();
    let mut buf = Vec::new();
    for _ in 0..steps {
        reference.draw_batch(&theta, m, &mut buf);
        want.extend_from_slice(&buf);
    }

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    let opts = ServeOptions::default();
    thread::scope(|scope| {
        let corer = &core;
        let stopr = &stop;
        let optsr = &opts;
        let server = scope.spawn(move || serve_supervised(corer, listener, stopr, optsr));

        let policy = RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(8),
        };
        let mut client =
            RetryClient::connect(&addr.to_string(), 77, ClientOptions::default(), policy).unwrap();
        let mut got = Vec::new();
        for step in 0..steps {
            if step == 2 {
                // the next client-side frame read dies mid-run
                faults::arm_at(faults::TCP_READ, Mode::Once, faults::SIDE_CLIENT);
            }
            let (_, draws) = client.draw(&theta, m).unwrap();
            got.extend_from_slice(&draws);
        }
        assert_eq!(faults::fires(faults::TCP_READ), 1, "the injected read failure fired");
        assert_eq!(client.retries(), 1, "exactly one reconnect");
        assert_eq!(want, got, "resumed stream diverged from the uninterrupted one");
        client.bye().unwrap();

        // the server is untouched: a fresh client draws, and STATS shows a
        // healthy front
        let mut fresh = ServeClient::connect(addr, 99).unwrap();
        let (_, extra) = fresh.draw(&theta, 5).unwrap();
        assert_eq!(extra.len(), 5);
        let stats = fresh.stats().unwrap();
        assert_eq!(stats.degraded_sessions, 0);
        fresh.bye().unwrap();

        stop.store(true, Ordering::Relaxed);
        let totals = server.join().unwrap().unwrap();
        // conn 2 (2 fast-forward replays + the retried step + step 3) and
        // conn 3 (the 5-draw health check) always land. Conn 1 adds its 3
        // served batches unless its handler lost the race writing the
        // reply the client never reads against the dropped connection —
        // in which case that handler's draws are not totalled and the
        // broken pipe counts as the (benign) connection error.
        let conn2_and_3 = (4 * m + 5) as u64;
        assert!(
            totals.draws == conn2_and_3 + (3 * m) as u64 || totals.draws == conn2_and_3,
            "unexpected draw total {}",
            totals.draws
        );
        assert_eq!(totals.connections, 3);
        assert!(totals.conn_errors <= 1, "only conn 1's benign write race may error");
        assert_eq!(totals.rejected_at_capacity, 0);
    });
}

/// Wire faults on the server's read path and the write path are isolated
/// to their connection: the victim client errors, the fault is counted,
/// and the next client is served normally — the front never exits.
#[test]
fn chaos_tcp_faults_are_counted_not_fatal() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let d = 6usize;
    let pre = setup(100, d, 191);
    let core = mk_core(&pre, 2);
    let theta = vec![0.05f32; d];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = AtomicBool::new(false);
    let opts = ServeOptions::default();
    thread::scope(|scope| {
        let corer = &core;
        let stopr = &stop;
        let optsr = &opts;
        let server = scope.spawn(move || serve_supervised(corer, listener, stopr, optsr));

        // server-side read failure: the handler errors, the HELLO never
        // answers, and the failure lands in conn_errors — not in Err
        faults::arm_at(faults::TCP_READ, Mode::Once, faults::SIDE_SERVER);
        assert!(ServeClient::connect(addr, 1).is_err());
        assert_eq!(faults::fires(faults::TCP_READ), 1);

        // client-side write failure: the HELLO frame never leaves the
        // process; the server just sees a connection that goes away
        faults::arm(faults::TCP_WRITE, Mode::Once);
        assert!(ServeClient::connect(addr, 2).is_err());
        assert_eq!(faults::fires(faults::TCP_WRITE), 1);

        // the front is unaffected
        let mut ok = ServeClient::connect(addr, 3).unwrap();
        let (_, draws) = ok.draw(&theta, 9).unwrap();
        assert_eq!(draws.len(), 9);
        ok.bye().unwrap();

        stop.store(true, Ordering::Relaxed);
        let totals = server.join().unwrap().unwrap();
        assert_eq!(totals.draws, 9);
        assert_eq!(totals.connections, 3);
        assert_eq!(totals.conn_errors, 1, "exactly the injected server-side read failure");
        assert_eq!(totals.rejected_at_capacity, 0);
    });
}

/// The determinism gate for the compiled-in registry: with failpoints
/// compiled in (this whole binary) but disarmed, pipelined serving still
/// replays the synchronous stream bit-for-bit and nothing degrades.
#[test]
fn chaos_disarmed_failpoints_leave_streams_identical() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let pre = setup(140, 8, 201);
    let core = mk_core(&pre, 3);
    let theta = vec![0.03f32; 8];
    let (m, steps) = (20usize, 5usize);
    let mut sync = ServingSession::open(&core, 17);
    let mut piped = ServingSession::open(&core, 17);
    let mut want = Vec::new();
    let mut buf = Vec::new();
    for _ in 0..steps {
        sync.draw_batch(&theta, m, &mut buf);
        want.extend_from_slice(&buf);
    }
    let mut got = Vec::new();
    let rep = piped
        .run_pipelined(&theta, m, steps, 64, |_, draws| {
            got.extend_from_slice(draws);
            true
        })
        .unwrap();
    assert!(!rep.degraded);
    assert_eq!(want, got, "disarmed failpoints changed a stream");
    assert_eq!(core.counters().degraded_sessions, 0);
}

// ---------------------------------------------------------------------------
// Training-loop health supervisor scenarios (GRAD_NAN / THETA_POISON /
// LOSS_CORRUPT). Shared shape: phase A trains two epochs cold with per-epoch
// autosaves, producing the rotation chain a rollback recovers from; the
// faulted resumed run is then compared against a disarmed reference resumed
// from the *same* snapshot — the contract is that recovery is not merely
// survival but bit-for-bit re-entry onto the reference trajectory.
// ---------------------------------------------------------------------------

/// Training split + test split + the base run config the health scenarios
/// share (sync sharded LGD, small constant-step batches).
fn train_setup(n: usize, seed: u64) -> (Preprocessed, Dataset, RunConfig) {
    let ds = SynthSpec::power_law("chaos-train", n, 8, seed).generate().unwrap();
    let (tr, te) = ds.split(0.8, 1).unwrap();
    let pre = preprocess(tr, &PreprocessOptions::default()).unwrap();
    let mut cfg = RunConfig::default();
    cfg.train.estimator = EstimatorKind::Lgd;
    cfg.train.epochs = 2;
    cfg.train.batch = 4;
    cfg.train.schedule = Schedule::Const(0.05);
    cfg.lsh.k = 4;
    cfg.lsh.l = 16;
    cfg.lsh.shards = 2;
    (pre, te, cfg)
}

/// Phase A: cold-train two epochs with per-epoch autosaves into `base`
/// (slot 0 = epoch 2, slot 1 = epoch 1), wiping any stale rotation files
/// first.
fn seed_snapshots(cfg: &mut RunConfig, pre: &Preprocessed, te: &Dataset, base: &std::path::Path) {
    for slot in 0..4 {
        let _ = std::fs::remove_file(rotated_path(base, slot));
    }
    cfg.store.path = Some(base.to_path_buf());
    cfg.store.autosave_epochs = 1;
    cfg.store.keep = 3;
    let cold = train(cfg, pre, te, GradSource::Native).unwrap();
    assert_eq!(cold.autosaves, 2, "phase A must leave a two-deep rotation chain");
}

/// The resumed-run config: two more epochs with the supervisor armed.
/// `rollback_lr_factor = 1.0` keeps the optimizer bitwise-identical after a
/// rollback so trajectories can be compared draw-for-draw.
fn resume_cfg(cfg: &RunConfig) -> RunConfig {
    let mut r = cfg.clone();
    r.train.epochs = 4;
    r.store.autosave_epochs = 0;
    r.health.enabled = true;
    r.health.rollback_lr_factor = 1.0;
    r
}

fn curve_key(out: &TrainOutcome) -> Vec<(u64, f64, f64)> {
    out.curve.iter().map(|p| (p.iter, p.train_loss, p.test_loss)).collect()
}

/// θ poisoned to NaN right after an optimizer step: the θ sentinel trips,
/// the run rolls back to the newest healthy snapshot and resumes — and the
/// resumed trajectory (curve, final θ) is bit-for-bit the disarmed
/// reference resumed from that same snapshot. Proven for the sync and the
/// async (pipelined) trainer.
#[test]
fn chaos_theta_poison_rolls_back_and_resumes_identical() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    for async_workers in [0usize, 2] {
        let (pre, te, mut cfg) = train_setup(300, 211);
        cfg.lsh.async_workers = async_workers;
        let base = std::env::temp_dir()
            .join(format!("lgd-chaos-health-theta-{async_workers}.lgdsnap"));
        seed_snapshots(&mut cfg, &pre, &te, &base);

        // disarmed reference: resume from the epoch-2 snapshot, no saves
        let mut ref_cfg = resume_cfg(&cfg);
        ref_cfg.store.path = None;
        let reference =
            train_resumed(&ref_cfg, &te, GradSource::Native, load(&base).unwrap()).unwrap();

        // faulted run: the first resumed step's update is poisoned
        let fault_cfg = resume_cfg(&cfg);
        faults::arm(faults::THETA_POISON, Mode::Once);
        let faulted =
            train_resumed(&fault_cfg, &te, GradSource::Native, load(&base).unwrap()).unwrap();
        assert_eq!(faults::fires(faults::THETA_POISON), 1, "async={async_workers}");
        assert_eq!(faulted.health.theta_trips, 1, "async={async_workers}");
        assert_eq!(faulted.health.rollbacks, 1, "async={async_workers}");
        assert_eq!(faulted.health.quarantined, 0, "θ poison blames no example");
        assert_eq!(
            curve_key(&faulted),
            curve_key(&reference),
            "async={async_workers}: post-rollback trajectory diverged from the reference"
        );
        assert_eq!(faulted.theta, reference.theta, "async={async_workers}");
        faults::disarm_all();
        for slot in 0..4 {
            let _ = std::fs::remove_file(rotated_path(&base, slot));
        }
    }
}

/// A persistently poisoned input: one drawn example's gradient contribution
/// is NaN on *every* draw (`Mode::Always`, filtered to its id). The grad
/// sentinel trips before the optimizer step, attribution blames exactly
/// that example, the rollback evicts it from the restored engine — and the
/// resumed run, which can never draw it again, matches bit-for-bit a
/// reference run that quarantined the id from the start via
/// `data.quarantine`. The fire count seals the eviction proof: the site is
/// armed Always, yet it fires only during the one poisoned batch.
#[test]
fn chaos_poisoned_example_is_quarantined_and_resume_matches_reference() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let (pre, te, mut cfg) = train_setup(300, 221);
    let base = std::env::temp_dir().join("lgd-chaos-health-grad.lgdsnap");
    seed_snapshots(&mut cfg, &pre, &te, &base);

    // Discovery: replay the resumed run's first batch draw to learn which
    // example id to poison (and how often it appears in that batch).
    let snap = load(&base).unwrap();
    let ts = snap.train.clone().unwrap();
    let LoadedSnapshot { pre: lpre, hasher, engine, .. } = snap;
    let mut probe = lgd::store::snapshot::restore_boxed(hasher, &lpre, engine).unwrap();
    let mut buf: Vec<WeightedDraw> = Vec::new();
    probe.draw_batch(&ts.theta, cfg.train.batch, &mut buf);
    let victim = buf[0].index;
    let count = buf.iter().filter(|d| d.index == victim).count() as u64;

    // disarmed reference: the victim is quarantined from step one
    let mut ref_cfg = resume_cfg(&cfg);
    ref_cfg.store.path = None;
    ref_cfg.data.quarantine = vec![victim];
    let reference =
        train_resumed(&ref_cfg, &te, GradSource::Native, load(&base).unwrap()).unwrap();
    assert_eq!(reference.health.quarantined, 0, "operator eviction is not a verdict");

    // faulted run: the victim's contribution is NaN forever
    let fault_cfg = resume_cfg(&cfg);
    faults::arm_at(faults::GRAD_NAN, Mode::Always, victim as u64);
    let faulted =
        train_resumed(&fault_cfg, &te, GradSource::Native, load(&base).unwrap()).unwrap();
    assert_eq!(faulted.health.grad_trips, 1, "one poisoned batch, one trip");
    assert_eq!(faulted.health.quarantined, 1, "the victim was evicted");
    assert_eq!(faulted.health.rollbacks, 1);
    // `count` fires in the accumulate pass + `count` in attribution, then
    // the evicted example is unreachable — Always never fires again.
    assert_eq!(
        faults::fires(faults::GRAD_NAN),
        2 * count,
        "an evicted example must never be drawn (or checked) again"
    );
    assert_eq!(
        curve_key(&faulted),
        curve_key(&reference),
        "quarantined resume diverged from the quarantined-from-the-start reference"
    );
    assert_eq!(faulted.theta, reference.theta);
    faults::disarm_all();
    for slot in 0..4 {
        let _ = std::fs::remove_file(rotated_path(&base, slot));
    }
}

/// A corrupted loss eval (NaN at the epoch-cadence eval) trips the loss
/// sentinel, rolls back, and the resumed run re-enters the reference
/// trajectory; the suppressed eval never reaches the curve.
#[test]
fn chaos_corrupt_loss_eval_rolls_back_and_curve_stays_clean() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let (pre, te, mut cfg) = train_setup(300, 231);
    let base = std::env::temp_dir().join("lgd-chaos-health-loss.lgdsnap");
    seed_snapshots(&mut cfg, &pre, &te, &base);

    let mut ref_cfg = resume_cfg(&cfg);
    ref_cfg.store.path = None;
    let reference =
        train_resumed(&ref_cfg, &te, GradSource::Native, load(&base).unwrap()).unwrap();

    let fault_cfg = resume_cfg(&cfg);
    // the entry eval is unchecked by design — Once lands on the first
    // *cadence* eval (end of epoch 3)
    faults::arm(faults::LOSS_CORRUPT, Mode::Once);
    let faulted =
        train_resumed(&fault_cfg, &te, GradSource::Native, load(&base).unwrap()).unwrap();
    assert_eq!(faults::fires(faults::LOSS_CORRUPT), 1);
    assert_eq!(faulted.health.loss_trips, 1);
    assert_eq!(faulted.health.rollbacks, 1);
    assert!(
        faulted.curve.iter().all(|p| p.train_loss.is_finite() && p.test_loss.is_finite()),
        "a tripping eval must never reach the curve"
    );
    assert_eq!(
        curve_key(&faulted),
        curve_key(&reference),
        "post-rollback trajectory diverged from the reference"
    );
    assert_eq!(faulted.theta, reference.theta);
    faults::disarm_all();
    for slot in 0..4 {
        let _ = std::fs::remove_file(rotated_path(&base, slot));
    }
}

/// Rollback exhaustion: a fault that persists across rollbacks (θ poisoned
/// on every step) burns the budget — `health.max_rollbacks` recoveries,
/// then a clean `Error::Health` carrying the final verdict, not a panic
/// and not an NaN-laced outcome.
#[test]
fn chaos_persistent_fault_exhausts_rollbacks_into_clean_error() {
    let _gate = serialize();
    faults::disarm_all();
    let _clean = Disarm;

    let (pre, te, mut cfg) = train_setup(300, 241);
    let base = std::env::temp_dir().join("lgd-chaos-health-exhaust.lgdsnap");
    seed_snapshots(&mut cfg, &pre, &te, &base);

    let mut fault_cfg = resume_cfg(&cfg);
    fault_cfg.health.max_rollbacks = 2;
    faults::arm(faults::THETA_POISON, Mode::Always);
    let err = train_resumed(&fault_cfg, &te, GradSource::Native, load(&base).unwrap())
        .unwrap_err();
    match &err {
        Error::Health(msg) => {
            assert!(msg.contains("rollback budget exhausted"), "{msg}");
            assert!(msg.contains("max_rollbacks = 2"), "{msg}");
        }
        other => panic!("want Error::Health, got {other:?}"),
    }
    // 2 successful rollbacks + the final straw = 3 poisoned steps
    assert_eq!(faults::fires(faults::THETA_POISON), 3);
    faults::disarm_all();
    for slot in 0..4 {
        let _ = std::fs::remove_file(rotated_path(&base, slot));
    }
}
